//! Topology fleet generator: named cloud-continuum shapes far beyond the
//! paper's five scenarios.
//!
//! Each shape produces a zoned [`Infrastructure`] (nodes carry `zone` and
//! [`Tier`] labels, carbon already enriched) plus a matching
//! [`Application`] whose communication graph is *clustered*: service
//! groups talk a lot internally and little across groups, which is the
//! regime where the [`crate::continuum`] zone partitioner pays off.
//!
//! Shapes:
//! * `cloud-edge-hierarchy` — a few big cloud datacentres, a regional
//!   middle tier, a long tail of small edge sites.
//! * `geo-regions` — uniform capacity split across geo regions whose
//!   carbon grids differ widely (the Forti & Brogi continuum setting).
//! * `iot-swarm` — one small cloud core plus swarms of constrained
//!   devices.
//! * `hybrid-burst` — a fixed on-prem zone plus elastic cloud burst
//!   zones (optional services overflow into the burst capacity).

use crate::model::{
    Application, CommLink, EnergyProfile, Flavour, Infrastructure, Node, Service, Tier,
};
use crate::util::Rng;
use crate::{Error, Result};

/// A named continuum shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    CloudEdgeHierarchy,
    GeoRegions,
    IotSwarm,
    HybridBurst,
}

impl Topology {
    /// Every shape, for sweeps.
    pub const ALL: [Topology; 4] = [
        Topology::CloudEdgeHierarchy,
        Topology::GeoRegions,
        Topology::IotSwarm,
        Topology::HybridBurst,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Topology::CloudEdgeHierarchy => "cloud-edge-hierarchy",
            Topology::GeoRegions => "geo-regions",
            Topology::IotSwarm => "iot-swarm",
            Topology::HybridBurst => "hybrid-burst",
        }
    }

    pub fn parse(s: &str) -> Result<Topology> {
        match s {
            "cloud-edge-hierarchy" => Ok(Topology::CloudEdgeHierarchy),
            "geo-regions" => Ok(Topology::GeoRegions),
            "iot-swarm" => Ok(Topology::IotSwarm),
            "hybrid-burst" => Ok(Topology::HybridBurst),
            other => Err(Error::Config(format!(
                "unknown topology '{other}' (expected one of: cloud-edge-hierarchy, \
                 geo-regions, iot-swarm, hybrid-burst)"
            ))),
        }
    }
}

/// Parameters of one generated fleet.
#[derive(Debug, Clone, Copy)]
pub struct TopologySpec {
    pub topology: Topology,
    pub nodes: usize,
    pub services: usize,
    /// Target number of zones (clamped to [1, nodes]).
    pub zones: usize,
    pub seed: u64,
}

impl TopologySpec {
    pub fn new(topology: Topology, nodes: usize, services: usize) -> TopologySpec {
        TopologySpec {
            topology,
            nodes: nodes.max(1),
            services: services.max(1),
            zones: 8,
            seed: 0xC0_411,
        }
    }

    pub fn with_zones(mut self, zones: usize) -> TopologySpec {
        self.zones = zones;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> TopologySpec {
        self.seed = seed;
        self
    }

    fn effective_zones(&self) -> usize {
        self.zones.clamp(1, self.nodes)
    }
}

/// Generate the full fleet: zoned infrastructure + clustered application.
pub fn generate(spec: &TopologySpec) -> (Application, Infrastructure) {
    let mut rng = Rng::new(spec.seed ^ spec.topology.name().len() as u64);
    let infra = generate_infrastructure(spec, &mut rng);
    let app = generate_application(spec, &mut rng);
    (app, infra)
}

/// The infrastructure side only (zone/tier-labelled, carbon enriched).
pub fn generate_infrastructure(spec: &TopologySpec, rng: &mut Rng) -> Infrastructure {
    let zones = spec.effective_zones();
    let mut infra = Infrastructure::new(format!("{}-{}", spec.topology.name(), spec.nodes));
    // per-zone grid character: base carbon intensity and base price
    let zone_ci: Vec<f64> = (0..zones).map(|_| rng.range(15.0, 600.0)).collect();
    let zone_cost: Vec<f64> = (0..zones).map(|_| rng.range(0.02, 0.12)).collect();

    for i in 0..spec.nodes {
        let z = i % zones;
        let frac = i as f64 / spec.nodes as f64;
        let mut n = Node::new(format!("node{i:04}"), format!("REG{z:02}"));
        n.zone = Some(format!("z{z:02}"));
        let jitter = rng.range(0.85, 1.15);
        n.profile.carbon = Some((zone_ci[z] * jitter).clamp(10.0, 650.0));
        n.profile.cost_per_cpu_hour = zone_cost[z] * rng.range(0.9, 1.1);
        match spec.topology {
            Topology::GeoRegions => {
                n.tier = Tier::Cloud;
                n.capabilities.cpu = rng.range(16.0, 64.0);
                n.capabilities.ram_gb = rng.range(32.0, 256.0);
            }
            Topology::CloudEdgeHierarchy => {
                // first 10% cloud, next 30% regional, remaining 60% edge
                if frac < 0.10 {
                    n.tier = Tier::Cloud;
                    n.capabilities.cpu = rng.range(64.0, 128.0);
                    n.capabilities.ram_gb = rng.range(256.0, 512.0);
                } else if frac < 0.40 {
                    n.tier = Tier::Regional;
                    n.capabilities.cpu = rng.range(16.0, 48.0);
                    n.capabilities.ram_gb = rng.range(64.0, 128.0);
                } else {
                    n.tier = Tier::Edge;
                    n.capabilities.cpu = rng.range(4.0, 8.0);
                    n.capabilities.ram_gb = rng.range(8.0, 16.0);
                    // edge sites often run on greener local grids
                    n.profile.carbon = Some((zone_ci[z] * jitter * 0.6).clamp(10.0, 650.0));
                }
            }
            Topology::IotSwarm => {
                if frac < 0.05 || i == 0 {
                    n.tier = Tier::Cloud;
                    n.capabilities.cpu = rng.range(64.0, 128.0);
                    n.capabilities.ram_gb = rng.range(128.0, 512.0);
                } else {
                    n.tier = Tier::Device;
                    n.capabilities.cpu = rng.range(1.0, 4.0);
                    n.capabilities.ram_gb = rng.range(1.0, 8.0);
                    n.capabilities.storage_gb = rng.range(4.0, 32.0);
                }
            }
            Topology::HybridBurst => {
                if z == 0 {
                    // the fixed on-prem estate: cheap, moderate capacity
                    n.tier = Tier::Regional;
                    n.capabilities.cpu = rng.range(16.0, 32.0);
                    n.capabilities.ram_gb = rng.range(32.0, 128.0);
                    n.profile.cost_per_cpu_hour = 0.02;
                } else {
                    // elastic burst capacity: bigger, pricier
                    n.tier = Tier::Cloud;
                    n.capabilities.cpu = rng.range(48.0, 128.0);
                    n.capabilities.ram_gb = rng.range(128.0, 512.0);
                    n.profile.cost_per_cpu_hour = zone_cost[z].max(0.06) * rng.range(1.0, 1.4);
                }
            }
        }
        infra.nodes.push(n);
    }
    infra
}

/// The application side only: clustered service groups whose intra-group
/// links are an order of magnitude chattier than cross-group links.
pub fn generate_application(spec: &TopologySpec, rng: &mut Rng) -> Application {
    let mut app = Application::new(format!("{}-{}svc", spec.topology.name(), spec.services));
    // demand scale: swarms must fit on device-class nodes
    let (cpu_cap, ram_cap) = match spec.topology {
        Topology::IotSwarm => (1.0, 2.0),
        Topology::CloudEdgeHierarchy => (4.0, 8.0),
        _ => (8.0, 16.0),
    };
    let group_size = (spec.services / spec.effective_zones().max(1)).clamp(4, 12);
    for i in 0..spec.services {
        let mut s = Service::new(format!("svc{i:04}"));
        // hybrid-burst models overflow work as optional services
        s.must_deploy = match spec.topology {
            Topology::HybridBurst => rng.chance(0.6),
            _ => rng.chance(0.9),
        };
        let base = rng.log_normal(-2.0, 2.0).min(8.0);
        let n_flavours = 1 + rng.below(3);
        for j in 0..n_flavours {
            let mut f = Flavour::new(match j {
                0 => "large".to_string(),
                1 => "medium".to_string(),
                _ => "tiny".to_string(),
            });
            let scale = 1.0 - 0.25 * j as f64;
            f.energy = Some(EnergyProfile {
                kwh: base * scale,
                samples: 24,
            });
            f.requirements.cpu = (0.25 + base * scale).min(cpu_cap);
            f.requirements.ram_gb = (0.25 + base * scale * 2.0).min(ram_cap);
            s.flavours.push(f);
        }
        app.services.push(s);
    }
    // communication: dense inside a group, sparse across groups
    let groups = (spec.services + group_size - 1) / group_size;
    for i in 0..spec.services {
        let g = i / group_size;
        let group_lo = g * group_size;
        let group_hi = ((g + 1) * group_size).min(spec.services);
        let span = group_hi - group_lo;
        // 2 chatty intra-group links
        for _ in 0..2 {
            if span < 2 {
                break;
            }
            let j = group_lo + rng.below(span);
            push_link(&mut app, i, j, rng.log_normal(-4.0, 1.0).min(1.0), rng);
        }
        // occasional thin cross-group link (group backbones)
        if groups > 1 && rng.chance(0.15) {
            let other_g = rng.below(groups);
            let lo = other_g * group_size;
            let hi = ((other_g + 1) * group_size).min(spec.services);
            if hi > lo {
                let j = lo + rng.below(hi - lo);
                push_link(&mut app, i, j, rng.log_normal(-7.0, 1.0).min(0.05), rng);
            }
        }
    }
    app
}

fn push_link(app: &mut Application, i: usize, j: usize, kwh: f64, _rng: &mut Rng) {
    if i == j {
        return;
    }
    let from = format!("svc{i:04}");
    let to = format!("svc{j:04}");
    if app.links.iter().any(|l| l.from == from && l.to == to) {
        return;
    }
    let mut link = CommLink::new(from, to);
    for f in &app.services[i].flavours {
        link.energy.push((f.name.clone(), kwh));
    }
    app.links.push(link);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(t: Topology) -> TopologySpec {
        TopologySpec::new(t, 60, 120).with_zones(4).with_seed(7)
    }

    #[test]
    fn every_shape_generates_valid_fleets() {
        for t in Topology::ALL {
            let (app, infra) = generate(&spec(t));
            assert_eq!(app.services.len(), 120, "{}", t.name());
            assert_eq!(infra.nodes.len(), 60, "{}", t.name());
            app.validate().unwrap();
            infra.validate().unwrap();
            // all nodes zoned and carbon-enriched
            for n in &infra.nodes {
                assert!(n.zone.is_some(), "{} node {} unzoned", t.name(), n.id);
                assert!(n.carbon() > 0.0);
            }
        }
    }

    #[test]
    fn parse_round_trips_names() {
        for t in Topology::ALL {
            assert_eq!(Topology::parse(t.name()).unwrap(), t);
        }
        assert!(Topology::parse("moonbase").is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&spec(Topology::GeoRegions));
        let b = generate(&spec(Topology::GeoRegions));
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }

    #[test]
    fn hierarchy_has_all_three_tiers() {
        let (_, infra) = generate(&spec(Topology::CloudEdgeHierarchy));
        for tier in [Tier::Cloud, Tier::Regional, Tier::Edge] {
            assert!(
                infra.nodes.iter().any(|n| n.tier == tier),
                "missing {tier:?}"
            );
        }
    }

    #[test]
    fn swarm_services_fit_device_nodes() {
        let (app, infra) = generate(&spec(Topology::IotSwarm));
        let max_cpu = app
            .rows()
            .iter()
            .map(|(_, f)| f.requirements.cpu)
            .fold(0.0, f64::max);
        let min_node = infra
            .nodes
            .iter()
            .map(|n| n.capabilities.cpu)
            .fold(f64::INFINITY, f64::min);
        assert!(
            max_cpu <= min_node,
            "service cpu {max_cpu} exceeds smallest device {min_node}"
        );
    }

    #[test]
    fn clustered_links_mostly_intra_group() {
        let (app, _) = generate(&spec(Topology::GeoRegions));
        let group = |id: &str| id[3..].parse::<usize>().unwrap() / 12;
        let intra = app
            .links
            .iter()
            .filter(|l| group(&l.from) == group(&l.to))
            .count();
        assert!(
            intra * 2 > app.links.len(),
            "expected mostly intra-group links ({intra}/{})",
            app.links.len()
        );
    }
}
