//! greengen — CLI for the Green-aware Constraint Generator.
//!
//! ```text
//! greengen scenario <1-5> [--explain] [--format prolog|json|minizinc] [--xla] [--extended]
//! greengen generate --app app.json --infra infra.json [--alpha 0.8] [--format prolog] [--xla]
//!                   [--incremental] [--epochs N]
//! greengen adaptive [--scenario 1] [--hours 48] [--regen 6] [--failures 0.0] [--xla]
//!                   [--incremental] [--zones N] [--horizon S]
//!                   [--trace FILE.jsonl] [--metrics FILE.prom]
//! greengen schedule [--scenario 1] [--solver greedy|exact|anneal|lns|portfolio|cost-only|random|oracle] [--seed N]
//! greengen scalability [--mode app|infra] [--steps 10] [--reps 3] [--out file.csv]
//! greengen threshold [--services 100] [--nodes 100]
//! greengen forecast [--scenario 3] [--train 48] [--eval 48] [--horizon 6] [--event 72]
//! greengen serve [--scenario 1] [--replay FILE.jsonl] [--deadline-ms 0] [--queue 1024]
//!                [--high-water N] [--retain-hours H] [--seed N] [--zones N]
//! greengen obs-summary FILE.jsonl [--metrics FILE.prom]
//! greengen info
//! ```

use greengen::adapter::{adapter_for, SchedulerAdapter};
use greengen::carbon::CarbonIntensitySource;
use greengen::cliargs::Args;
use greengen::config::scenarios;
use greengen::forecast::{
    AccuracyConfig, BlendedForecaster, CarbonForecaster, EwmaDrift, SeasonalNaive,
};
use greengen::continuum::{IncrementalReplanner, ShardedScheduler, ZonePartitioner};
use greengen::pipeline::{AdaptiveConfig, AdaptiveLoop, GeneratorPipeline, PipelineConfig};
use greengen::runtime::{AnalyticsBackend, NativeBackend, XlaBackend};
use greengen::scheduler::{
    evaluate, solver_by_name_threads, GreedyScheduler, Objective, Problem, Scheduler, SOLVER_NAMES,
};
use greengen::serve::{Daemon, ServeConfig};
use greengen::telemetry::EnergyMeter;
use greengen::util::{quantile_lower, Cell, Rng, Row};
use greengen::{simulate, Result};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("scenario") => cmd_scenario(args),
        Some("generate") => cmd_generate(args),
        Some("adaptive") => cmd_adaptive(args),
        Some("schedule") => cmd_schedule(args),
        Some("crosscheck") => cmd_crosscheck(args),
        Some("scalability") => cmd_scalability(args),
        Some("threshold") => cmd_threshold(args),
        Some("timeshift") => cmd_timeshift(args),
        Some("forecast") => cmd_forecast(args),
        Some("continuum") => cmd_continuum(args),
        Some("serve") => cmd_serve(args),
        Some("obs-summary") => cmd_obs_summary(args),
        Some("info") => cmd_info(),
        Some("help") | None => {
            print!("{}", USAGE);
            Ok(())
        }
        Some(other) => Err(greengen::Error::Config(format!(
            "unknown command '{other}' (see `greengen help`)"
        ))),
    }
}

const USAGE: &str = "\
greengen — Green by Design: constraint-based adaptive deployment

USAGE:
  greengen scenario <1-5> [--explain] [--format prolog|json|minizinc] [--xla] [--extended]
  greengen generate --app app.json --infra infra.json [--alpha 0.8] [--format prolog] [--xla]
                    [--incremental] [--epochs N] [--threads N]
  greengen adaptive [--scenario 1] [--hours 48] [--regen 6] [--failures 0.0]
                    [--incremental] [--zones N] [--horizon S] [--threads N]
                    [--trace FILE.jsonl] [--metrics FILE.prom]
  greengen schedule [--scenario 1] [--solver greedy|exact|anneal|lns|portfolio|cost-only|random|oracle]
                    [--seed N] [--threads N] [--trace FILE.jsonl] [--metrics FILE.prom]
  greengen crosscheck [--scenario 1] [--solver portfolio] [--seed N] [--corrupt]
  greengen scalability [--mode app|infra] [--steps 10] [--reps 3] [--out file.csv]
  greengen threshold [--services 100] [--nodes 100]
  greengen timeshift [--scenario 1] [--window 4] [--horizon 24] [--forecast]
  greengen forecast [--scenario 3] [--train 48] [--eval 48] [--horizon 6] [--event 72]
  greengen continuum [--topology geo-regions] [--nodes 500] [--services 1000] [--zones 8]
                     [--solver sharded|monolithic|both|all] [--epochs 1] [--sequential] [--seed N]
                     [--threads N] [--trace FILE.jsonl] [--metrics FILE.prom]
  greengen serve [--scenario 1] [--replay FILE.jsonl] [--deadline-ms 0] [--queue 1024]
                 [--high-water N] [--retain-hours H] [--seed N] [--zones N]
                 [--threads N] [--trace FILE.jsonl] [--metrics FILE.prom]
  greengen obs-summary FILE.jsonl [--metrics FILE.prom]
  greengen info

Topologies: cloud-edge-hierarchy, geo-regions, iot-swarm, hybrid-burst
Solver ladder (docs/solvers.md): greedy -> anneal -> lns -> portfolio -> exact
";

/// Switch tracing / metrics collection on when `--trace` / `--metrics`
/// name an output file. With neither flag this is a no-op and every
/// instrumented site stays on its one-relaxed-load fast path.
fn obs_setup(args: &Args) {
    if args.opt("trace").is_some() {
        greengen::obs::trace::set_enabled(true);
    }
    if args.opt("metrics").is_some() {
        greengen::obs::metrics::set_enabled(true);
    }
}

/// Flush collected observability data to the files named by `--trace`
/// (JSONL spans) and `--metrics` (Prometheus text exposition). Status
/// goes to stderr so stdout stays exactly the report it always was.
fn obs_finish(args: &Args) -> Result<()> {
    if let Some(path) = args.opt("trace") {
        let records = greengen::obs::trace::drain();
        greengen::obs::trace::write_jsonl(std::path::Path::new(path), &records)?;
        eprintln!("# trace: {} spans -> {path}", records.len());
    }
    if let Some(path) = args.opt("metrics") {
        let now_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as i64)
            .unwrap_or(0);
        let registry = greengen::obs::metrics::global();
        std::fs::write(path, registry.render(now_ms))?;
        eprintln!("# metrics: {} series -> {path}", registry.series_count());
    }
    Ok(())
}

fn cmd_obs_summary(args: &Args) -> Result<()> {
    args.ensure_known(&["metrics"])?;
    let path = args.positional.first().ok_or_else(|| {
        greengen::Error::Config("trace file required (greengen obs-summary FILE.jsonl)".into())
    })?;
    let records = greengen::obs::trace::read_jsonl(std::path::Path::new(path))?;
    let stats = greengen::obs::trace::aggregate(&records);
    let header = Row::new()
        .cell(Cell::left("stage", 22))
        .sep(" ")
        .cell(Cell::right("count", 8))
        .sep(" ")
        .cell(Cell::right("total_ms", 12))
        .sep(" ")
        .cell(Cell::right("self_ms", 12))
        .finish();
    println!("{header}");
    for s in &stats {
        let line = Row::new()
            .cell(Cell::left(&s.name, 22))
            .sep(" ")
            .cell(Cell::right(s.count, 8))
            .sep(" ")
            .cell(Cell::fixed(s.total_us as f64 / 1e3, 12, 3))
            .sep(" ")
            .cell(Cell::fixed(s.self_us as f64 / 1e3, 12, 3))
            .finish();
        println!("{line}");
    }
    println!("\n{} spans across {} stages", records.len(), stats.len());
    if let Some(mpath) = args.opt("metrics") {
        let text = std::fs::read_to_string(mpath)?;
        let registry = greengen::obs::metrics::Registry::from_exposition(&text)?;
        println!(
            "metrics: {} series re-ingested from {mpath}",
            registry.series_count()
        );
    }
    Ok(())
}

fn pipeline(args: &Args) -> Result<GeneratorPipeline> {
    let mut config = PipelineConfig::default();
    config.generator.alpha = args.f64_or("alpha", 0.8)?;
    config.extended_library = args.flag("extended");
    config.threads = args.usize_or("threads", 1)?;
    if args.flag("direct") {
        config.generator.use_prolog = false;
    }
    if args.flag("xla") {
        GeneratorPipeline::with_xla(config, &args.opt_or("artifacts", "artifacts"))
    } else {
        Ok(GeneratorPipeline::new(config))
    }
}

fn cmd_scenario(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "explain", "format", "xla", "extended", "alpha", "direct", "artifacts",
    ])?;
    let n: usize = args
        .positional
        .first()
        .ok_or_else(|| greengen::Error::Config("scenario number required (1-5)".into()))?
        .parse()
        .map_err(|_| greengen::Error::Config("scenario must be a number".into()))?;
    let scenario = scenarios::scenario(n)?;
    println!(
        "# Scenario {n}: {} — {}",
        scenario.name, scenario.description
    );
    let mut pipe = pipeline(args)?;
    let outcome = pipe.run_scenario(&scenario)?;
    println!(
        "# backend={} tau={:.3} constraints={}",
        pipe.backend_name(),
        outcome.raw.tau,
        outcome.ranked.len()
    );
    let adapter = adapter(args)?;
    print!("{}", adapter.format(&outcome.ranked));
    if args.flag("explain") {
        println!("\n{}", outcome.report.render_text());
    }
    Ok(())
}

fn adapter(args: &Args) -> Result<Box<dyn SchedulerAdapter>> {
    let name = args.opt_or("format", "prolog");
    adapter_for(&name)
        .ok_or_else(|| greengen::Error::Config(format!("unknown format '{name}'")))
}

fn cmd_generate(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "app", "infra", "alpha", "format", "xla", "extended", "direct", "artifacts", "explain",
        "incremental", "epochs", "threads",
    ])?;
    let app_path = args
        .opt("app")
        .ok_or_else(|| greengen::Error::Config("--app required".into()))?;
    let infra_path = args
        .opt("infra")
        .ok_or_else(|| greengen::Error::Config("--infra required".into()))?;
    let mut app = greengen::config::load_application(std::path::Path::new(app_path))?;
    let mut infra = greengen::config::load_infrastructure(std::path::Path::new(infra_path))?;

    // Carbon enrichment: region lookup against the paper's tables; nodes
    // with explicit carbon values keep them.
    let mut static_all = greengen::carbon::StaticIntensity::europe_table2();
    for (region, value) in [
        ("US-WA", 244.0),
        ("US-CA", 235.0),
        ("US-TX", 231.0),
        ("US-FL", 570.0),
        ("US-NY", 236.0),
        ("US-AZ", 229.0),
    ] {
        static_all.set(region, value);
    }
    let gatherer = greengen::carbon::EnergyMixGatherer::new(&static_all);
    gatherer.enrich(&mut infra, 0.0)?;

    let mut pipe = pipeline(args)?;
    let store = greengen::monitoring::MetricStore::new(); // profiles come from the file
    let outcome = if args.flag("incremental") {
        // run the incremental engine for --epochs generations over the
        // same inputs: epoch 0 is the cold full pass, later epochs report
        // 0 dirty rows — the warm-start demo (the adaptive loop feeds it
        // *changing* inputs and pays only for what moved)
        let epochs = args.usize_or("epochs", 2)?.max(1);
        let mut last = None;
        for epoch in 0..epochs {
            let outcome = pipe.run_incremental(&mut app, &mut infra, &store, &static_all, 0.0)?;
            let stats = outcome.incremental.expect("incremental stats");
            // telemetry goes to stderr: stdout stays clean for the
            // machine-readable adapter output (--format json|minizinc)
            eprintln!(
                "# epoch {epoch}: dirty_rows {}/{}  dirty_nodes {}  full_rebuild {}  \
                 tau_changed {}  constraints {}",
                stats.dirty_rows,
                stats.total_rows,
                stats.dirty_nodes,
                stats.full_rebuild,
                stats.tau_changed,
                outcome.ranked.len()
            );
            last = Some(outcome);
        }
        last.expect("epochs >= 1")
    } else {
        pipe.run_epoch(&mut app, &mut infra, &store, &static_all, 0.0)?
    };
    print!("{}", adapter(args)?.format(&outcome.ranked));
    if args.flag("explain") {
        println!("\n{}", outcome.report.render_text());
    }
    Ok(())
}

fn cmd_adaptive(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "scenario", "hours", "regen", "failures", "xla", "alpha", "extended", "direct",
        "artifacts", "seed", "incremental", "zones", "horizon", "trace", "metrics", "threads",
    ])?;
    obs_setup(args);
    let scenario = scenarios::scenario(args.usize_or("scenario", 1)?)?;
    let incremental = args.flag("incremental");
    let horizon = args.usize_or("horizon", 0)?;
    let config = AdaptiveConfig {
        hours: args.usize_or("hours", 48)?,
        regen_every: args.usize_or("regen", 6)?,
        failure_rate: args.f64_or("failures", 0.0)?,
        objective: Objective::default(),
        seed: args.u64_or("seed", 0xADA9)?,
        incremental,
        zones: args.usize_or("zones", 0)?,
        horizon,
        threads: args.usize_or("threads", 1)?,
    };
    let mut looper = AdaptiveLoop::with_pipeline(pipeline(args)?, config);
    let summary = looper.run(&scenario)?;
    let mut header =
        String::from("hour  #constraints  constrained_g  cost_only_g  random_g  oracle_g  failed");
    if incremental {
        header.push_str("  rows(dirty/total)  zones(dirty/total)  reused  improver_gain");
    }
    if horizon > 0 {
        header.push_str("  projected_g  swings");
    }
    println!("{header}");
    for e in &summary.epochs {
        let mut row = Row::new()
            .cell(Cell::right(e.hour, 4))
            .gap()
            .cell(Cell::right(e.constraints, 12))
            .gap()
            .cell(Cell::fixed(e.constrained_g, 13, 1))
            .gap()
            .cell(Cell::fixed(e.cost_only_g, 11, 1))
            .gap()
            .cell(Cell::fixed(e.random_g, 8, 1))
            .gap()
            .cell(Cell::fixed(e.oracle_g, 8, 1))
            .gap()
            .cell(Cell::right(e.failed_node.as_deref().unwrap_or("-"), 0));
        if incremental {
            row = row
                .gap()
                .cell(Cell::right(e.gen_dirty_rows, 6))
                .sep("/")
                .cell(Cell::left(e.gen_total_rows, 6))
                .sep(" ")
                .cell(Cell::right(e.dirty_zones, 6))
                .sep("/")
                .cell(Cell::left(e.total_zones, 6))
                .sep(" ")
                .cell(Cell::right(e.reused_placements, 6))
                .gap()
                .cell(Cell::fixed(e.improver_gain, 13, 3));
        }
        if horizon > 0 {
            row = row
                .gap()
                .cell(Cell::fixed(e.projected_g, 11, 1))
                .gap()
                .cell(Cell::right(e.predicted_swings, 6));
        }
        println!("{}", row.finish());
    }
    println!(
        "\ntotals (gCO2eq): constrained={:.1} cost-only={:.1} random={:.1} oracle={:.1}",
        summary.total_constrained_g,
        summary.total_cost_only_g,
        summary.total_random_g,
        summary.total_oracle_g
    );
    println!(
        "emission reduction vs cost-only: {:.1}%  (oracle recovery {:.1}%)",
        summary.reduction_vs_cost_only() * 100.0,
        summary.oracle_recovery() * 100.0
    );
    println!(
        "forecast-projected emissions (horizon {} slots): {:.1} gCO2eq",
        horizon, summary.total_projected_g
    );
    obs_finish(args)?;
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "scenario", "solver", "seed", "threads", "xla", "alpha", "extended", "direct", "artifacts",
        "trace", "metrics",
    ])?;
    obs_setup(args);
    let scenario = scenarios::scenario(args.usize_or("scenario", 1)?)?;
    let mut pipe = pipeline(args)?;
    let outcome = pipe.run_scenario(&scenario)?;

    // re-enrich a fresh copy for the scheduling problem
    let mut app = scenario.app.clone();
    let mut infra = scenario.infra.clone();
    let mut sim =
        greengen::monitoring::WorkloadSimulator::new(scenario.truth.clone(), scenario.seed);
    let store = sim.run(0.0, scenario.windows);
    let estimator = greengen::energy::EnergyEstimator::default();
    estimator.estimate(&mut app, &store);
    let gatherer = greengen::carbon::EnergyMixGatherer::new(&scenario.intensity);
    gatherer.enrich(&mut infra, store.horizon())?;

    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &outcome.ranked,
        objective: Objective::default(),
    };
    let solver_name = args.opt_or("solver", "greedy");
    let seed = args.u64_or("seed", 7)?;
    let threads = args.usize_or("threads", 1)?;
    let solver = solver_by_name_threads(&solver_name, seed, threads).ok_or_else(|| {
        greengen::Error::Config(format!(
            "unknown solver '{solver_name}' (expected one of: {})",
            SOLVER_NAMES.join("|")
        ))
    })?;
    let (plan, cert) = solver.certified_schedule(&problem)?;
    let metrics = evaluate(&problem, &plan)?;
    println!("# solver={solver_name} constraints={}", outcome.ranked.len());
    println!(
        "# certificate: objective={:.6} lower_bound={:.6} gap={:.6}",
        cert.objective,
        cert.lower_bound,
        cert.gap.max(0.0)
    );
    for p in &plan.placements {
        println!("deploy {} ({}) -> {}", p.service, p.flavour, p.node);
    }
    for d in &plan.dropped {
        println!("drop   {d}");
    }
    println!(
        "\nemissions={:.1} gCO2eq/window  cost={:.3}/h  violations={} (weight {:.2})  dropped={}",
        metrics.emissions_g,
        metrics.cost,
        metrics.violations,
        metrics.violation_weight,
        metrics.dropped
    );
    obs_finish(args)?;
    Ok(())
}

/// `greengen crosscheck`: solve a scenario, certify the plan, then run
/// the independent declarative (Prolog) checker against the compiled
/// evaluator. Exits non-zero when the two evaluators disagree *or* when
/// both flag the plan (the latter is the expected outcome under
/// `--corrupt`, which deliberately damages the plan first — CI uses it
/// to prove the checker actually bites).
fn cmd_crosscheck(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "scenario", "solver", "seed", "threads", "corrupt", "xla", "alpha", "extended", "direct",
        "artifacts", "trace", "metrics",
    ])?;
    obs_setup(args);
    let scenario = scenarios::scenario(args.usize_or("scenario", 1)?)?;
    let mut pipe = pipeline(args)?;
    let outcome = pipe.run_scenario(&scenario)?;

    let mut app = scenario.app.clone();
    let mut infra = scenario.infra.clone();
    let mut sim =
        greengen::monitoring::WorkloadSimulator::new(scenario.truth.clone(), scenario.seed);
    let store = sim.run(0.0, scenario.windows);
    let estimator = greengen::energy::EnergyEstimator::default();
    estimator.estimate(&mut app, &store);
    let gatherer = greengen::carbon::EnergyMixGatherer::new(&scenario.intensity);
    gatherer.enrich(&mut infra, store.horizon())?;

    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &outcome.ranked,
        objective: Objective::default(),
    };
    let solver_name = args.opt_or("solver", "portfolio");
    let seed = args.u64_or("seed", 7)?;
    let threads = args.usize_or("threads", 1)?;
    let solver = solver_by_name_threads(&solver_name, seed, threads).ok_or_else(|| {
        greengen::Error::Config(format!(
            "unknown solver '{solver_name}' (expected one of: {})",
            SOLVER_NAMES.join("|")
        ))
    })?;
    let (mut plan, cert) = solver.certified_schedule(&problem)?;
    println!(
        "# crosscheck: solver={solver_name} constraints={} objective={:.6} lower_bound={:.6} gap={:.6}",
        outcome.ranked.len(),
        cert.objective,
        cert.lower_bound,
        cert.gap.max(0.0)
    );
    if args.flag("corrupt") {
        corrupt_plan(&mut plan, &app, &infra);
        println!("# corrupt: dropped a mandatory service and piled placements onto one node");
    }
    let report = greengen::constraints::cross_check(&problem, &plan)?;
    print!("{}", report.render_text());
    if !report.agrees() {
        return Err(greengen::Error::other(
            "declarative checker disagrees with the compiled evaluator",
        ));
    }
    if !report.clean() {
        return Err(greengen::Error::Infeasible(
            "both checkers flag the plan as violating hard guarantees".to_string(),
        ));
    }
    println!("# crosscheck: compiled and declarative checkers agree; plan is clean");
    obs_finish(args)?;
    Ok(())
}

/// Deliberately damage a plan so both checkers must flag it: drop the
/// first placed mandatory service, then pile every remaining placement
/// onto the first node.
fn corrupt_plan(
    plan: &mut greengen::model::DeploymentPlan,
    app: &greengen::model::Application,
    infra: &greengen::model::Infrastructure,
) {
    if let Some(victim) = app
        .services
        .iter()
        .find(|s| s.must_deploy && plan.is_deployed(&s.id))
    {
        plan.placements.retain(|p| p.service != victim.id);
        plan.dropped.push(victim.id.clone());
    }
    if let Some(first) = infra.nodes.first() {
        for p in &mut plan.placements {
            p.node = first.id.clone();
        }
    }
}

fn cmd_scalability(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "mode", "steps", "reps", "out", "xla", "direct", "artifacts", "nodes", "services",
    ])?;
    let mode = args.opt_or("mode", "app");
    let steps = args.usize_or("steps", 10)?;
    let reps = args.usize_or("reps", 3)?;
    let fixed_nodes = args.usize_or("nodes", 50)?;
    let fixed_services = args.usize_or("services", 100)?;

    let xla = if args.flag("xla") {
        Some(XlaBackend::from_artifacts(args.opt_or("artifacts", "artifacts"))?)
    } else {
        None
    };
    let native = NativeBackend;
    let backend: &dyn AnalyticsBackend = match &xla {
        Some(b) => b,
        None => &native,
    };

    println!(
        "mode={mode} steps={steps} reps={reps} backend={}",
        backend.name()
    );
    println!("size,components,nodes,mean_seconds,mean_kwh,constraints");
    let mut csv = String::from("size,components,nodes,mean_seconds,mean_kwh,constraints\n");
    for step in 1..=steps {
        let (services, nodes) = match mode.as_str() {
            "app" => (step * 100, fixed_nodes),
            "infra" => (fixed_services, step * 20),
            other => return Err(greengen::Error::Config(format!("unknown mode '{other}'"))),
        };
        let mut seconds = 0.0;
        let mut kwh = 0.0;
        let mut n_constraints = 0usize;
        for rep in 0..reps {
            let mut rng = Rng::new((step * 1000 + rep) as u64);
            let app = simulate::random_application(&mut rng, services);
            let infra = simulate::random_infrastructure(&mut rng, nodes);
            let generator = greengen::constraints::ConstraintGenerator::new(backend)
                .with_config(greengen::constraints::GeneratorConfig {
                    alpha: 0.8,
                    use_prolog: false, // Fig. 2 measures the numeric pipeline
                });
            let mut meter = EnergyMeter::default();
            let result = meter.measure("generate", || generator.generate(&app, &infra))?;
            let ranked = greengen::ranker::Ranker::default().rank_fresh(&result.constraints);
            let report = greengen::explain::ExplainabilityGenerator::report(
                &greengen::constraints::ConstraintLibrary::default(),
                &ranked,
            );
            let _ = meter.measure("explain", || report.render_text().len());
            let (t, e) = meter.totals();
            seconds += t;
            kwh += e;
            n_constraints = ranked.len();
        }
        let line = format!(
            "{step},{services},{nodes},{:.4},{:.6e},{n_constraints}",
            seconds / reps as f64,
            kwh / reps as f64
        );
        println!("{line}");
        csv.push_str(&line);
        csv.push('\n');
    }
    if let Some(path) = args.opt("out") {
        std::fs::write(path, csv)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_threshold(args: &Args) -> Result<()> {
    args.ensure_known(&["services", "nodes", "xla", "direct", "artifacts", "seed"])?;
    let services = args.usize_or("services", 100)?;
    let nodes = args.usize_or("nodes", 100)?;
    let seed = args.usize_or("seed", 77)? as u64;

    let mut rng = Rng::new(seed);
    let app = simulate::random_application(&mut rng, services);
    let infra = simulate::random_infrastructure(&mut rng, nodes);
    let backend = NativeBackend;

    println!("# Table 4: constraints per quantile level ({services} services x {nodes} nodes)");
    println!("quantile,tau,constraints");
    let mut all_ems: Vec<f64> = Vec::new();
    for level in [0.90, 0.85, 0.80, 0.75, 0.70, 0.65, 0.60, 0.55, 0.50] {
        let generator = greengen::constraints::ConstraintGenerator::new(&backend).with_config(
            greengen::constraints::GeneratorConfig {
                alpha: level,
                use_prolog: false,
            },
        );
        let result = generator.generate(&app, &infra)?;
        println!("{level},{:.2},{}", result.tau, result.constraints.len());
        if level == 0.50 {
            all_ems = result.constraints.iter().map(|c| c.em).collect();
        }
    }
    // Fig. 3 data: savings distribution of the α=0.5 superset
    all_ems.sort_by(|a, b| b.partial_cmp(a).unwrap());
    println!("\n# Fig 3: potential savings of constraints, most impactful first");
    println!("rank,em_gCO2eq");
    for (i, em) in all_ems.iter().enumerate().take(40) {
        println!("{},{:.2}", i + 1, em);
    }
    println!(
        "# tail: q80 of pooled impacts = {:.2}",
        quantile_lower(&all_ems, 0.8)
    );
    Ok(())
}

fn cmd_timeshift(args: &Args) -> Result<()> {
    args.ensure_known(&["scenario", "window", "horizon", "forecast"])?;
    let scenario = scenarios::scenario(args.usize_or("scenario", 1)?)?;
    // learn profiles from simulated monitoring, then plan against the
    // diurnal CI forecast of every region in the scenario infrastructure
    let mut app = scenario.app.clone();
    let mut sim =
        greengen::monitoring::WorkloadSimulator::new(scenario.truth.clone(), scenario.seed);
    let store = sim.run(0.0, scenario.windows);
    greengen::energy::EnergyEstimator::default().estimate(&mut app, &store);

    let traces = GeneratorPipeline::trace_set(&scenario);
    let regions: Vec<String> = scenario.infra.nodes.iter().map(|n| n.region.clone()).collect();
    let region_refs: Vec<&str> = regions.iter().map(|r| r.as_str()).collect();
    let t0 = store.horizon();

    // --forecast: score windows on an honest model trained on the trace
    // history *up to the planning origin only* — observing past t0 would
    // hand the seasonal lookup the very future it is asked to predict
    let mut forecaster = BlendedForecaster::new();
    if args.flag("forecast") {
        let mut h = 0usize;
        loop {
            let t = h as f64 * 3600.0;
            if t > t0 {
                break;
            }
            for region in &regions {
                if let Some(v) = traces.intensity(region, t) {
                    forecaster.observe(region, t, v);
                }
            }
            h += 1;
        }
    }
    let mut planner = if args.flag("forecast") {
        greengen::constraints::TimeShiftPlanner::with_forecast(&forecaster)
    } else {
        greengen::constraints::TimeShiftPlanner::new(&traces)
    };
    planner.window_hours = args.usize_or("window", 4)?;
    planner.horizon_hours = args.usize_or("horizon", 24)?;
    let recs = planner.plan(&app, &region_refs, t0)?;
    if recs.is_empty() {
        println!("no batch-capable services with learned profiles");
        return Ok(());
    }
    for rec in &recs {
        println!("{}", rec.render_prolog(1.0));
        println!("{}\n", rec.explain());
    }
    Ok(())
}

fn cmd_forecast(args: &Args) -> Result<()> {
    args.ensure_known(&["scenario", "train", "eval", "horizon", "event"])?;
    let scenario = scenarios::scenario(args.usize_or("scenario", 3)?)?;
    let config = AccuracyConfig {
        train_hours: args.usize_or("train", 48)?,
        eval_hours: args.usize_or("eval", 48)?,
        horizon_hours: args.usize_or("horizon", 6)?,
        step_hours: 1,
    };
    let event_hour = args.usize_or("event", config.train_hours + config.eval_hours / 2)?;

    // Ground truth: the scenario's diurnal traces. For Scenario 3 the
    // table perturbation (France 16 -> 376) becomes a *temporal event*
    // at --event: the grid runs on the unperturbed table before it and
    // on the scenario table after — exactly the renewable-dropout
    // dynamic the scenario describes. Scenarios whose table equals the
    // baseline have no event and the run is purely diurnal.
    let (before, after) = scenarios::event_trace_sets(scenario.id)?;
    let event_t = event_hour as f64 * 3600.0;
    let uses_event = scenario.id == 3;
    let truth = |region: &str, t: f64| -> Option<f64> {
        if uses_event && t < event_t {
            before.intensity(region, t)
        } else {
            after.intensity(region, t)
        }
    };

    let mut regions: Vec<String> =
        scenario.infra.nodes.iter().map(|n| n.region.clone()).collect();
    regions.sort();
    regions.dedup();
    let region_refs: Vec<&str> = regions.iter().map(|r| r.as_str()).collect();

    let mut seasonal = SeasonalNaive::diurnal();
    let mut ewma = EwmaDrift::new();
    let mut blended = BlendedForecaster::new();
    let report = greengen::forecast::accuracy::walk_forward(
        truth,
        &region_refs,
        &config,
        &mut [&mut seasonal, &mut ewma, &mut blended],
    );

    println!(
        "# forecast accuracy — scenario {} ({}), horizon {} h",
        scenario.id, scenario.name, config.horizon_hours
    );
    if uses_event {
        println!(
            "# walk-forward: {} h train + {} h eval, brown-out event at hour {}",
            config.train_hours, config.eval_hours, event_hour
        );
    } else {
        println!(
            "# walk-forward: {} h train + {} h eval (purely diurnal trace)",
            config.train_hours, config.eval_hours
        );
    }
    print!("{}", report.render_text());
    for region in &regions {
        if let Some((ws, we)) = blended.weights(region) {
            println!("# blended weights {region}: seasonal {ws:.2}, drift {we:.2}");
        }
    }
    if let (Some(b), Some(s)) = (report.case("blended"), report.case("seasonal-naive")) {
        if s.mape > 0.0 {
            println!(
                "# blended vs seasonal-naive: {:+.1}% MAPE ({} better)",
                (b.mape - s.mape) / s.mape * 100.0,
                if b.mape < s.mape { "blended" } else { "seasonal" }
            );
        }
    }
    Ok(())
}

/// One solver's result line in the continuum comparison.
struct SolveRow {
    seconds: f64,
    objective: f64,
}

fn continuum_row(
    name: &str,
    problem: &Problem,
    plan: &greengen::model::DeploymentPlan,
    seconds: f64,
) -> Result<SolveRow> {
    let metrics = evaluate(problem, plan)?;
    let objective = problem.objective_value(&problem.to_assignment(plan)?);
    let line = Row::new()
        .cell(Cell::left(name, 22))
        .sep(" ")
        .cell(Cell::fixed(seconds * 1e3, 9, 1))
        .sep(" ms  objective ")
        .cell(Cell::fixed(objective, 12, 2))
        .sep("  emissions ")
        .cell(Cell::fixed(metrics.emissions_g, 11, 1))
        .sep(" g  cost ")
        .cell(Cell::fixed(metrics.cost, 8, 3))
        .sep("/h  violations ")
        .cell(Cell::right(metrics.violations, 4))
        .sep(" (w ")
        .cell(Cell::fixed(metrics.violation_weight, 0, 2))
        .sep(")  dropped ")
        .cell(Cell::right(metrics.dropped, 0))
        .finish();
    println!("{line}");
    Ok(SolveRow { seconds, objective })
}

fn cmd_continuum(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "topology", "nodes", "services", "zones", "seed", "solver", "alpha", "epochs",
        "sequential", "threads", "trace", "metrics",
    ])?;
    obs_setup(args);
    let topology = simulate::Topology::parse(&args.opt_or("topology", "geo-regions"))?;
    let nodes = args.usize_or("nodes", 500)?;
    let services = args.usize_or("services", 1000)?;
    let zones = args.usize_or("zones", 8)?;
    let seed = args.u64_or("seed", 0xC0_411)?;
    let spec = simulate::TopologySpec::new(topology, nodes, services)
        .with_zones(zones)
        .with_seed(seed);
    let (app, mut infra) = simulate::topology::generate(&spec);
    println!(
        "# continuum: topology={} nodes={} services={} zones={}",
        topology.name(),
        nodes,
        services,
        zones
    );

    // learn green constraints on the numeric fast path, then rank them
    let backend = NativeBackend;
    let generated = greengen::constraints::ConstraintGenerator::new(&backend)
        .with_config(greengen::constraints::GeneratorConfig {
            alpha: args.f64_or("alpha", 0.8)?,
            use_prolog: false,
        })
        .generate(&app, &infra)?;
    let constraints = greengen::ranker::Ranker::default().rank_fresh(&generated.constraints);
    println!(
        "# constraints={} tau={:.2}",
        constraints.len(),
        generated.tau
    );

    let threads = args.usize_or("threads", 1)?;
    let objective = Objective::default();
    let mut sharded = ShardedScheduler {
        parallel: !args.flag("sequential"),
        threads,
        ..ShardedScheduler::default()
    };
    if zones > 0 {
        sharded.partitioner = ZonePartitioner::with_zones(zones);
    }
    let solver_mode = args.opt_or("solver", "both");
    if !matches!(
        solver_mode.as_str(),
        "sharded" | "monolithic" | "both" | "all"
    ) {
        return Err(greengen::Error::Config(format!(
            "unknown solver '{solver_mode}' (sharded|monolithic|both|all)"
        )));
    }

    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &constraints,
        objective,
    };
    let mut mono: Option<SolveRow> = None;
    let mut shard: Option<SolveRow> = None;
    if matches!(solver_mode.as_str(), "monolithic" | "both" | "all") {
        let t0 = std::time::Instant::now();
        let plan = GreedyScheduler {
            threads,
            ..GreedyScheduler::default()
        }
        .schedule(&problem)?;
        mono = Some(continuum_row(
            "monolithic-greedy",
            &problem,
            &plan,
            t0.elapsed().as_secs_f64(),
        )?);
    }
    if matches!(solver_mode.as_str(), "sharded" | "both" | "all") {
        let t0 = std::time::Instant::now();
        let (plan, stats, cert) = sharded.certified_schedule_with_stats(&problem)?;
        let seconds = t0.elapsed().as_secs_f64();
        shard = Some(continuum_row("sharded-continuum", &problem, &plan, seconds)?);
        println!(
            "# sharded: mode={} zones={} repair_placed={} repair_moves={}",
            stats.mode, stats.zones, stats.repair_placed, stats.repair_moves
        );
        println!(
            "# certificate: objective={:.6} lower_bound={:.6} gap={:.6}",
            cert.objective,
            cert.lower_bound,
            cert.gap.max(0.0)
        );
    }
    if solver_mode == "all" {
        // the local-search ladder on the same instance (docs/solvers.md)
        for name in ["anneal", "lns", "portfolio"] {
            let solver = solver_by_name_threads(name, seed, threads).expect("registry solver");
            let t0 = std::time::Instant::now();
            let plan = solver.schedule(&problem)?;
            continuum_row(solver.name(), &problem, &plan, t0.elapsed().as_secs_f64())?;
        }
    }
    if let (Some(m), Some(s)) = (&mono, &shard) {
        println!(
            "# speedup x{:.2}  objective gap {:+.2}%",
            m.seconds / s.seconds.max(1e-9),
            (s.objective - m.objective) / m.objective.max(1e-9) * 100.0
        );
    }

    // --- incremental re-planning demo: one zone's grid drifts per epoch
    let epochs = args.usize_or("epochs", 1)?;
    if epochs > 1 {
        println!("\n# incremental re-planning: one zone's grid drifts each epoch");
        let mut rp = IncrementalReplanner::new(sharded);
        // mirror TopologySpec::effective_zones: the generator clamps the
        // requested zone count to the node count, and drift must target a
        // zone label that actually exists
        let live_zones = zones.clamp(1, nodes);
        for e in 0..epochs {
            if e > 0 {
                let zone = format!("z{:02}", e % live_zones);
                let factor = if e % 2 == 0 { 0.6 } else { 1.6 };
                for n in &mut infra.nodes {
                    if n.zone.as_deref() == Some(zone.as_str()) {
                        n.profile.carbon = Some((n.carbon() * factor).clamp(10.0, 650.0));
                    }
                }
            }
            let problem = Problem {
                app: &app,
                infra: &infra,
                constraints: &constraints,
                objective,
            };
            let t0 = std::time::Instant::now();
            let outcome = rp.replan(&problem)?;
            let metrics = evaluate(&problem, &outcome.plan)?;
            let line = Row::new()
                .sep("epoch ")
                .cell(Cell::right(e, 3))
                .sep(": dirty ")
                .cell(Cell::right(outcome.dirty_zones.len(), 0))
                .sep("/")
                .cell(Cell::right(outcome.total_zones, 0))
                .sep(" zones  reused ")
                .cell(Cell::right(outcome.reused_placements, 5))
                .sep(" placements  ")
                .cell(Cell::fixed(t0.elapsed().as_secs_f64() * 1e3, 8, 1))
                .sep(" ms  emissions ")
                .cell(Cell::fixed(metrics.emissions_g, 0, 1))
                .sep(" g  gap ")
                .cell(Cell::fixed(outcome.certificate.gap.max(0.0), 0, 3))
                .finish();
            println!("{line}");
        }
    }
    obs_finish(args)?;
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "scenario",
        "replay",
        "deadline-ms",
        "queue",
        "high-water",
        "retain-hours",
        "seed",
        "zones",
        "threads",
        "alpha",
        "extended",
        "direct",
        "xla",
        "artifacts",
        "trace",
        "metrics",
    ])?;
    obs_setup(args);
    let scenario = scenarios::scenario(args.usize_or("scenario", 1)?)?;
    let queue = args.usize_or("queue", 1024)?;
    let config = ServeConfig {
        queue,
        high_water: args.usize_or("high-water", queue / 2)?,
        deadline_ms: args.u64_or("deadline-ms", 0)?,
        live: args.opt("replay").is_none(),
        seed: args.u64_or("seed", 0x5EBF)?,
        zones: args.usize_or("zones", 0)?,
        retain_hours: args.f64_or("retain-hours", 0.0)?,
        threads: args.usize_or("threads", 1)?,
        objective: Objective::default(),
    };
    let mut daemon = Daemon::new(&scenario, pipeline(args)?, config);
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let stderr = std::io::stderr();
    let mut status = stderr.lock();
    let summary = match args.opt("replay") {
        Some(path) => {
            let file = std::fs::File::open(path)?;
            let mut input = std::io::BufReader::new(file);
            daemon.run(&mut input, &mut out, &mut status)?
        }
        None => {
            let stdin = std::io::stdin();
            let mut input = stdin.lock();
            daemon.run(&mut input, &mut out, &mut status)?
        }
    };
    drop(out);
    drop(status);
    eprintln!(
        "# serve: {} epochs ({} full, {} incremental), {} events, {} responses",
        summary.epochs,
        summary.epochs_full,
        summary.epochs_incremental,
        summary.events,
        summary.responses
    );
    obs_finish(args)?;
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("greengen {}", env!("CARGO_PKG_VERSION"));
    match XlaBackend::from_default_artifacts() {
        Ok(backend) => {
            println!("xla backend: available");
            for b in backend.buckets() {
                println!(
                    "  bucket {}x{} (pool {}) <- {}",
                    b.rows,
                    b.nodes,
                    b.pool,
                    b.file.display()
                );
            }
        }
        Err(e) => println!("xla backend: unavailable ({e}); native fallback in use"),
    }
    Ok(())
}
