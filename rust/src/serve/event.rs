//! The serve event-stream wire format: one JSON object per line, typed
//! by a `"type"` field.
//!
//! The stream carries the monitoring signals the adaptive loop
//! otherwise scrapes from the simulator (energy and traffic samples),
//! control-plane changes (carbon-intensity updates, node churn),
//! placement requests, the epoch clock (`tick`), and `shutdown`:
//!
//! | `type`           | fields                                                   |
//! |------------------|----------------------------------------------------------|
//! | `metric_energy`  | `t`, `service`, `flavour`, `joules`                      |
//! | `metric_traffic` | `t`, `from`, `from_flavour`, `to`, `requests`, `bytes`   |
//! | `carbon`         | `region`, `intensity` (gCO2eq/kWh override)              |
//! | `node_down`      | `node`                                                   |
//! | `node_up`        | `node`                                                   |
//! | `request`        | `id`, `kind` (`"plan"` or `"replan"`)                    |
//! | `tick`           | `t` (seconds — runs one adaptive epoch)                  |
//! | `shutdown`       | —                                                        |
//!
//! Parsing is strict per type (missing/mistyped fields are errors the
//! daemon counts as `malformed`), but an *unrecognised* `"type"` parses
//! to [`Event::Unknown`] so the daemon can count it separately and keep
//! going — forward compatibility over strictness.

use crate::jsonio;
use crate::monitoring::{EnergySample, TrafficSample};
use crate::{Error, Result};

/// What a `request` event asks for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestKind {
    /// Answer with the next epoch's plan.
    Plan,
    /// Reset the incremental re-planner's carried state first, then
    /// answer with a from-scratch plan.
    Replan,
}

/// One parsed event line.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A Kepler-style energy observation.
    MetricEnergy(EnergySample),
    /// An Istio-style traffic observation.
    MetricTraffic(TrafficSample),
    /// Carbon-intensity override for a grid region.
    Carbon {
        /// Grid region (must name a region of the infrastructure).
        region: String,
        /// New intensity, gCO2eq/kWh.
        intensity: f64,
    },
    /// A node left the infrastructure.
    NodeDown {
        /// Node id.
        node: String,
    },
    /// A previously-downed node rejoined.
    NodeUp {
        /// Node id.
        node: String,
    },
    /// A placement request; answered after the next epoch.
    Request {
        /// Caller-chosen correlation id, echoed in the response.
        id: String,
        /// Plan or replan.
        kind: RequestKind,
    },
    /// Epoch clock: run one adaptive epoch at simulated time `t`.
    Tick {
        /// Simulated time, seconds.
        t: f64,
    },
    /// Stop the daemon after flushing pending requests.
    Shutdown,
    /// Well-formed JSON with an unrecognised `"type"` (skipped and
    /// counted by the daemon).
    Unknown(String),
}

/// Parse one JSONL event line.
pub fn parse_event(line: &str) -> Result<Event> {
    let v = jsonio::parse(line)?;
    let kind = v.str_field("type")?;
    Ok(match kind {
        "metric_energy" => Event::MetricEnergy(EnergySample {
            t: v.f64_field("t")?,
            service: v.str_field("service")?.to_string(),
            flavour: v.str_field("flavour")?.to_string(),
            joules: v.f64_field("joules")?,
        }),
        "metric_traffic" => Event::MetricTraffic(TrafficSample {
            t: v.f64_field("t")?,
            from: v.str_field("from")?.to_string(),
            from_flavour: v.str_field("from_flavour")?.to_string(),
            to: v.str_field("to")?.to_string(),
            requests: v.f64_field("requests")?,
            bytes: v.f64_field("bytes")?,
        }),
        "carbon" => Event::Carbon {
            region: v.str_field("region")?.to_string(),
            intensity: v.f64_field("intensity")?,
        },
        "node_down" => Event::NodeDown {
            node: v.str_field("node")?.to_string(),
        },
        "node_up" => Event::NodeUp {
            node: v.str_field("node")?.to_string(),
        },
        "request" => {
            let id = v.str_field("id")?.to_string();
            let kind = match v.str_field("kind")? {
                "plan" => RequestKind::Plan,
                "replan" => RequestKind::Replan,
                other => {
                    return Err(Error::Json(format!("unknown request kind `{other}`")));
                }
            };
            Event::Request { id, kind }
        }
        "tick" => Event::Tick {
            t: v.f64_field("t")?,
        },
        "shutdown" => Event::Shutdown,
        other => Event::Unknown(other.to_string()),
    })
}

/// Stable label for an event's type — metric label values must come
/// from a bounded set, so [`Event::Unknown`] maps to `"unknown"`
/// regardless of the payload string.
pub fn event_label(event: &Event) -> &'static str {
    match event {
        Event::MetricEnergy(_) => "metric_energy",
        Event::MetricTraffic(_) => "metric_traffic",
        Event::Carbon { .. } => "carbon",
        Event::NodeDown { .. } => "node_down",
        Event::NodeUp { .. } => "node_up",
        Event::Request { .. } => "request",
        Event::Tick { .. } => "tick",
        Event::Shutdown => "shutdown",
        Event::Unknown(_) => "unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_event_type() {
        let lines = [
            (
                r#"{"type":"metric_energy","t":3600,"service":"frontend","flavour":"large","joules":90000}"#,
                "metric_energy",
            ),
            (
                r#"{"type":"metric_traffic","t":3600,"from":"frontend","from_flavour":"large","to":"checkout","requests":120,"bytes":480000}"#,
                "metric_traffic",
            ),
            (r#"{"type":"carbon","region":"FR","intensity":92.5}"#, "carbon"),
            (r#"{"type":"node_down","node":"france"}"#, "node_down"),
            (r#"{"type":"node_up","node":"france"}"#, "node_up"),
            (r#"{"type":"request","id":"r1","kind":"plan"}"#, "request"),
            (r#"{"type":"tick","t":7200}"#, "tick"),
            (r#"{"type":"shutdown"}"#, "shutdown"),
        ];
        for (line, label) in lines {
            let ev = parse_event(line).unwrap();
            assert_eq!(event_label(&ev), label, "line {line}");
        }
    }

    #[test]
    fn energy_fields_land_in_the_sample() {
        let ev = parse_event(
            r#"{"type":"metric_energy","t":7200,"service":"cart","flavour":"tiny","joules":1234.5}"#,
        )
        .unwrap();
        let Event::MetricEnergy(s) = ev else {
            panic!("wrong variant");
        };
        assert_eq!(s.t, 7200.0);
        assert_eq!(s.service, "cart");
        assert_eq!(s.flavour, "tiny");
        assert_eq!(s.joules, 1234.5);
    }

    #[test]
    fn unknown_type_is_not_an_error() {
        let ev = parse_event(r#"{"type":"telemetry_v2","payload":1}"#).unwrap();
        assert_eq!(ev, Event::Unknown("telemetry_v2".to_string()));
    }

    #[test]
    fn malformed_lines_error() {
        assert!(parse_event("{not json").is_err());
        assert!(parse_event(r#"{"no_type":1}"#).is_err());
        // missing required field for a known type
        assert!(parse_event(r#"{"type":"tick"}"#).is_err());
        // bad request kind
        assert!(parse_event(r#"{"type":"request","id":"r1","kind":"destroy"}"#).is_err());
    }
}
