//! `greengen serve` — the long-running scheduler daemon.
//!
//! The paper's architecture is a *continuously-running* control loop:
//! monitoring feeds constraint learning feeds re-planning. This module
//! closes that loop as a daemon: a JSONL event stream (stdin, or a file
//! in `--replay` mode) carries monitoring samples, carbon-intensity
//! updates, node churn and placement requests; `tick` events drive
//! adaptive epochs through the same [`crate::pipeline::EpochCycle`] the
//! one-shot CLI benchmarks, and each epoch answers with JSONL on
//! stdout.
//!
//! Three design rules keep the daemon testable:
//!
//! 1. **No threads, no timers.** Epochs run only on `tick` events, so
//!    the output is a pure function of the event sequence + seed, and
//!    live stdin and `--replay` take the identical code path.
//! 2. **Bounded ingest.** Events buffer in fixed-capacity drop-oldest
//!    [`Ring`]s; overload sheds the *oldest* observations, counted and
//!    exported — never silent, never unbounded.
//! 3. **Deterministic stdout.** Wall-clock numbers (epoch latency) go
//!    to stderr and the metrics histogram only. `--deadline-ms` scales
//!    solver iteration budgets deterministically ([`budgets`]) and, in
//!    live mode only, additionally arms real wall-clock deadlines in
//!    the anytime solvers.
//!
//! See `docs/serve.md` for the wire format and the degradation ladder.

mod daemon;
mod event;
mod ring;

pub use daemon::{budgets, Daemon, ServeConfig, ServeSummary};
pub use event::{event_label, parse_event, Event, RequestKind};
pub use ring::Ring;
