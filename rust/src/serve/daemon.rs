//! The serve daemon: a synchronous, zero-thread event loop that turns
//! the one-shot adaptive pipeline into a long-running scheduler.
//!
//! Events arrive line-by-line (stdin in live mode, a file in `--replay`
//! mode) and are buffered in two bounded drop-oldest [`Ring`]s:
//! `samples` (energy + traffic observations) and `control`
//! (carbon overrides, node churn, placement requests). `tick` and
//! `shutdown` are handled by the loop directly. An epoch runs **only on
//! a `tick`** — there are no timers or threads — so the sequence of
//! epochs is a pure function of the event sequence and live mode and
//! replay mode take the identical path.
//!
//! **Degradation ladder.** At each tick the daemon measures how many
//! events are pending. At or above `--high-water` it degrades from the
//! full pass (complete constraint regeneration + portfolio search) to
//! the incremental path ([`GeneratorPipeline::run_incremental`] +
//! [`IncrementalReplanner`]) — O(changed) work when the stream is hot.
//! The epoch line carries the mode actually taken.
//!
//! **Deadlines.** `--deadline-ms` bounds each epoch two ways: solver
//! iteration budgets are scaled *deterministically* from the budget via
//! [`budgets`], and — in live mode only — a wall-clock deadline is
//! armed through the anytime solvers. Replay mode never arms wall
//! clocks, and stdout carries no wall-clock numbers (latency goes to
//! stderr and the metrics histogram), so replay output is byte-stable.

use super::event::{event_label, parse_event, Event, RequestKind};
use super::ring::Ring;
use crate::carbon::TraceSet;
use crate::config::Scenario;
use crate::continuum::{IncrementalReplanner, ShardedScheduler, ZonePartitioner};
use crate::jsonio::{self, Value};
use crate::model::{Application, Infrastructure};
use crate::monitoring::MetricStore;
use crate::obs::metrics;
use crate::pipeline::{EpochCycle, GeneratorPipeline};
use crate::scheduler::{Objective, PortfolioScheduler};
use crate::Result;
use std::collections::BTreeSet;
use std::io::{BufRead, Write};
use std::time::{Duration, Instant};

/// Daemon configuration (one `greengen serve` invocation).
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Capacity of each ingest ring (samples and control).
    pub queue: usize,
    /// Pending-event count at which an epoch degrades to the
    /// incremental path.
    pub high_water: usize,
    /// Per-epoch wall-clock budget in milliseconds; `0` disables both
    /// the wall deadline and the budget-derived iteration scaling.
    pub deadline_ms: u64,
    /// Live mode arms real wall-clock deadlines; replay mode keeps
    /// epochs iteration-budgeted only (deterministic output).
    pub live: bool,
    /// Solver seed (identical seed + identical event sequence →
    /// byte-identical replay output).
    pub seed: u64,
    /// Zone-count hint for the sharded re-planner (0 = labels/auto).
    pub zones: usize,
    /// Drop monitoring samples older than this many hours at each tick
    /// (`0` keeps the full history).
    pub retain_hours: f64,
    /// Worker threads for each epoch's constraint generation, portfolio
    /// scoring/racing, and the incremental re-planner (1 = sequential;
    /// any value produces byte-identical output — see
    /// `scheduler::parscore` and `constraints::generator::run_library`).
    pub threads: usize,
    /// Scheduling objective.
    pub objective: Objective,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue: 1024,
            high_water: 512,
            deadline_ms: 0,
            live: false,
            seed: 0x5EBF,
            zones: 0,
            retain_hours: 0.0,
            threads: 1,
            objective: Objective::default(),
        }
    }
}

/// Deterministic solver iteration budgets derived from the epoch
/// deadline: `(anneal_iterations, lns_rounds, improve_iterations,
/// racers)`.
///
/// `deadline_ms == 0` returns today's fixed defaults. Otherwise budgets
/// scale linearly with the deadline and clamp to `[floor, default]`, so
/// a tight budget shrinks the search the same way on every machine —
/// the wall clock (live mode only) is just the backstop. `racers` is
/// the portfolio's seed-race width: tight deadlines keep a single
/// racer (all iterations go to one trajectory), roomy ones restore the
/// default four-way race.
pub fn budgets(deadline_ms: u64) -> (usize, usize, usize, usize) {
    if deadline_ms == 0 {
        return (20_000, 12, 4_000, 4);
    }
    let ms = deadline_ms as usize;
    (
        (ms * 40).clamp(512, 20_000),
        (ms / 16).clamp(2, 12),
        (ms * 10).clamp(256, 4_000),
        (ms / 64).clamp(1, 4),
    )
}

#[derive(Debug, Default, Clone, Copy)]
struct Counters {
    events: u64,
    responses: u64,
    epochs_full: u64,
    epochs_incremental: u64,
    malformed: u64,
    unknown_type: u64,
    unknown_name: u64,
    stale: u64,
}

/// End-of-run accounting; also emitted as the final `summary` line.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    /// Epochs run (full + incremental).
    pub epochs: u64,
    /// Epochs that took the full-regeneration path.
    pub epochs_full: u64,
    /// Epochs that degraded to the incremental path.
    pub epochs_incremental: u64,
    /// Well-formed events ingested (including skipped ones).
    pub events: u64,
    /// Plan responses emitted.
    pub responses: u64,
    /// Sample-ring evictions (drop-oldest backpressure).
    pub dropped_samples: u64,
    /// Control-ring evictions.
    pub dropped_control: u64,
    /// Lines that failed to parse.
    pub skipped_malformed: u64,
    /// Well-formed events with an unrecognised `"type"`.
    pub skipped_unknown_type: u64,
    /// Events naming an unknown service/flavour/node/region.
    pub skipped_unknown_name: u64,
    /// Events with out-of-order timestamps.
    pub skipped_stale: u64,
    /// True when the run ended on a `shutdown` event (false = EOF).
    pub shutdown: bool,
}

impl ServeSummary {
    /// Render as the final stdout JSONL line.
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("type", Value::from("summary")),
            ("epochs", Value::from(self.epochs as usize)),
            ("epochs_full", Value::from(self.epochs_full as usize)),
            (
                "epochs_incremental",
                Value::from(self.epochs_incremental as usize),
            ),
            ("events", Value::from(self.events as usize)),
            ("responses", Value::from(self.responses as usize)),
            ("dropped_samples", Value::from(self.dropped_samples as usize)),
            ("dropped_control", Value::from(self.dropped_control as usize)),
            (
                "skipped_malformed",
                Value::from(self.skipped_malformed as usize),
            ),
            (
                "skipped_unknown_type",
                Value::from(self.skipped_unknown_type as usize),
            ),
            (
                "skipped_unknown_name",
                Value::from(self.skipped_unknown_name as usize),
            ),
            ("skipped_stale", Value::from(self.skipped_stale as usize)),
            ("shutdown", Value::from(self.shutdown)),
        ])
    }
}

/// The long-running scheduler daemon. See the module docs for the loop
/// structure; construct with [`Daemon::new`], drive with [`Daemon::run`].
pub struct Daemon {
    config: ServeConfig,
    app: Application,
    base_infra: Infrastructure,
    regions: BTreeSet<String>,
    down: BTreeSet<String>,
    traces: TraceSet,
    store: MetricStore,
    pipeline: GeneratorPipeline,
    replanner: IncrementalReplanner,
    samples: Ring<Event>,
    control: Ring<Event>,
    counters: Counters,
    last_t: f64,
    epoch: u64,
    shutdown: bool,
}

impl Daemon {
    /// Build a daemon over a scenario's application + infrastructure.
    /// The pipeline carries the constraint KB across epochs; pass the
    /// same pipeline the one-shot commands build so flags like
    /// `--extended` apply.
    pub fn new(scenario: &Scenario, mut pipeline: GeneratorPipeline, config: ServeConfig) -> Daemon {
        let mut sharded = ShardedScheduler::default();
        sharded.threads = config.threads.max(1);
        if config.zones > 0 {
            sharded.partitioner = ZonePartitioner::with_zones(config.zones);
        }
        let mut replanner = IncrementalReplanner::new(sharded);
        pipeline.config.threads = config.threads.max(1);
        let (_, _, improve_iterations, _) = budgets(config.deadline_ms);
        replanner.config.improve_iterations = improve_iterations;
        Daemon {
            app: scenario.app.clone(),
            base_infra: scenario.infra.clone(),
            regions: scenario.infra.nodes.iter().map(|n| n.region.clone()).collect(),
            down: BTreeSet::new(),
            traces: GeneratorPipeline::trace_set(scenario),
            store: MetricStore::new(),
            pipeline,
            replanner,
            samples: Ring::new(config.queue),
            control: Ring::new(config.queue),
            counters: Counters::default(),
            last_t: 0.0,
            epoch: 0,
            shutdown: false,
            config,
        }
    }

    /// Drive the daemon until `shutdown` or end-of-stream, writing
    /// response JSONL to `out` and human-readable epoch latencies to
    /// `status` (stderr). An unreadable input line (I/O error) ends the
    /// stream the same way EOF does. Returns the final summary, which
    /// is also the last `out` line.
    pub fn run(
        &mut self,
        input: &mut dyn BufRead,
        out: &mut dyn Write,
        status: &mut dyn Write,
    ) -> Result<ServeSummary> {
        let mut line = String::new();
        loop {
            line.clear();
            let n = match input.read_line(&mut line) {
                Ok(n) => n,
                Err(_) => {
                    // undecodable input: count it and treat the stream
                    // as ended — retrying cannot make progress
                    self.skip("malformed");
                    break;
                }
            };
            if n == 0 {
                break; // EOF (covers mid-stream truncation)
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            self.ingest(trimmed, out, status)?;
            if self.shutdown {
                break;
            }
        }
        self.finish(out, status)
    }

    fn ingest(&mut self, line: &str, out: &mut dyn Write, status: &mut dyn Write) -> Result<()> {
        let event = match parse_event(line) {
            Ok(ev) => ev,
            Err(_) => {
                self.skip("malformed");
                return Ok(());
            }
        };
        self.counters.events += 1;
        metrics::counter_add(
            "greengen_sched_serve_events_total",
            &[("type", event_label(&event))],
            1.0,
        );
        match event {
            Event::Unknown(_) => self.skip("unknown_type"),
            Event::Shutdown => self.shutdown = true,
            Event::Tick { t } => {
                if t <= self.last_t {
                    self.skip("stale");
                } else {
                    self.epoch_tick(t, out, status)?;
                }
            }
            Event::MetricEnergy(s) => {
                let known = self
                    .app
                    .service(&s.service)
                    .is_some_and(|sv| sv.flavour(&s.flavour).is_some());
                if s.t <= self.last_t {
                    self.skip("stale");
                } else if !known {
                    self.skip("unknown_name");
                } else {
                    self.buffer_sample(Event::MetricEnergy(s));
                }
            }
            Event::MetricTraffic(s) => {
                let known = self
                    .app
                    .service(&s.from)
                    .is_some_and(|sv| sv.flavour(&s.from_flavour).is_some())
                    && self.app.service(&s.to).is_some();
                if s.t <= self.last_t {
                    self.skip("stale");
                } else if !known {
                    self.skip("unknown_name");
                } else {
                    self.buffer_sample(Event::MetricTraffic(s));
                }
            }
            Event::Carbon { region, intensity } => {
                if !self.regions.contains(&region) {
                    self.skip("unknown_name");
                } else {
                    self.buffer_control(Event::Carbon { region, intensity });
                }
            }
            churn @ (Event::NodeDown { .. } | Event::NodeUp { .. }) => {
                let known = match &churn {
                    Event::NodeDown { node } | Event::NodeUp { node } => {
                        self.base_infra.nodes.iter().any(|n| n.id == *node)
                    }
                    _ => false,
                };
                if !known {
                    self.skip("unknown_name");
                } else {
                    self.buffer_control(churn);
                }
            }
            request @ Event::Request { .. } => self.buffer_control(request),
        }
        Ok(())
    }

    fn skip(&mut self, reason: &'static str) {
        match reason {
            "malformed" => self.counters.malformed += 1,
            "unknown_type" => self.counters.unknown_type += 1,
            "unknown_name" => self.counters.unknown_name += 1,
            "stale" => self.counters.stale += 1,
            _ => {}
        }
        metrics::counter_add(
            "greengen_sched_serve_skipped_total",
            &[("reason", reason)],
            1.0,
        );
    }

    fn buffer_sample(&mut self, event: Event) {
        if self.samples.push(event).is_some() {
            metrics::counter_add(
                "greengen_sched_serve_dropped_total",
                &[("queue", "samples")],
                1.0,
            );
        }
    }

    fn buffer_control(&mut self, event: Event) {
        if self.control.push(event).is_some() {
            metrics::counter_add(
                "greengen_sched_serve_dropped_total",
                &[("queue", "control")],
                1.0,
            );
        }
    }

    /// Run one adaptive epoch at simulated time `t`: apply control
    /// events, flush samples into the store, generate + schedule +
    /// evaluate through the shared [`EpochCycle`], answer pending
    /// requests.
    fn epoch_tick(&mut self, t: f64, out: &mut dyn Write, status: &mut dyn Write) -> Result<()> {
        let sample_depth = self.samples.len();
        let control_depth = self.control.len();
        let queued = sample_depth + control_depth;
        let incremental = queued >= self.config.high_water;
        let started = Instant::now();
        let mut span = crate::span!("serve.epoch", {
            epoch: self.epoch,
            queued: queued,
        });

        // control plane first: the epoch sees carbon/node churn that
        // arrived before its tick
        let mut requests: Vec<String> = Vec::new();
        for ev in self.control.drain() {
            match ev {
                Event::Carbon { region, intensity } => {
                    self.traces.override_region(&region, intensity);
                }
                Event::NodeDown { node } => {
                    self.down.insert(node);
                }
                Event::NodeUp { node } => {
                    self.down.remove(&node);
                }
                Event::Request { id, kind } => {
                    if kind == RequestKind::Replan {
                        self.replanner.reset();
                    }
                    requests.push(id);
                }
                _ => {}
            }
        }
        for ev in self.samples.drain() {
            match ev {
                Event::MetricEnergy(s) => self.store.push_energy(s),
                Event::MetricTraffic(s) => self.store.push_traffic(s),
                _ => {}
            }
        }
        if self.config.retain_hours > 0.0 {
            self.store.compact(t - self.config.retain_hours * 3600.0);
        }

        // epoch infrastructure: the base topology minus downed nodes
        let mut infra = self.base_infra.clone();
        let down = &self.down;
        infra.nodes.retain(|n| !down.contains(&n.id));

        // arm the budgets: iteration scaling always (deterministic),
        // wall-clock deadlines in live mode only
        let (anneal_iterations, lns_rounds, _, racers) = budgets(self.config.deadline_ms);
        let wall = (self.config.live && self.config.deadline_ms > 0)
            .then(|| Duration::from_millis(self.config.deadline_ms));
        self.replanner.config.improve_deadline = wall.map(|d| started + d);
        let mut portfolio = PortfolioScheduler::seeded(self.config.seed);
        portfolio.anneal_iterations = anneal_iterations;
        portfolio.lns_rounds = lns_rounds;
        portfolio.racers = racers;
        portfolio.threads = self.config.threads.max(1);
        portfolio.deadline = wall;

        let cycle = EpochCycle {
            pipeline: &mut self.pipeline,
            incremental,
            replanner: incremental.then_some(&mut self.replanner),
            solver: &portfolio,
            objective: self.config.objective,
        }
        .run(&mut self.app, &mut infra, &self.store, &self.traces, t)?;

        let mode = if incremental { "incremental" } else { "full" };
        let epoch_line = Value::object(vec![
            ("type", Value::from("epoch")),
            ("epoch", Value::from(self.epoch as usize)),
            ("t", Value::from(t)),
            ("mode", Value::from(mode)),
            ("queued", Value::from(queued)),
            ("dropped_samples", Value::from(self.samples.dropped() as usize)),
            ("dropped_control", Value::from(self.control.dropped() as usize)),
            ("constraints", Value::from(cycle.ranked.len())),
            ("placed", Value::from(cycle.plan.placements.len())),
            ("dropped_services", Value::from(cycle.plan.dropped.len())),
            ("emissions_g", Value::from(cycle.metrics.emissions_g)),
            ("cost", Value::from(cycle.metrics.cost)),
            ("dirty_zones", Value::from(cycle.dirty_zones)),
            ("total_zones", Value::from(cycle.total_zones)),
            ("reused_placements", Value::from(cycle.reused_placements)),
            ("gen_dirty_rows", Value::from(cycle.gen_dirty_rows)),
            ("gen_total_rows", Value::from(cycle.gen_total_rows)),
            ("lower_bound", Value::from(cycle.certificate.lower_bound)),
            ("gap", Value::from(cycle.certificate.gap)),
        ]);
        writeln!(out, "{}", jsonio::to_string(&epoch_line))?;

        for id in &requests {
            let response = Value::object(vec![
                ("type", Value::from("plan")),
                ("id", Value::from(id.as_str())),
                ("epoch", Value::from(self.epoch as usize)),
                ("mode", Value::from(mode)),
                ("emissions_g", Value::from(cycle.metrics.emissions_g)),
                ("plan", cycle.plan.to_json()),
            ]);
            writeln!(out, "{}", jsonio::to_string(&response))?;
            self.counters.responses += 1;
        }

        // wall-clock figures stay off stdout: stderr + histogram only
        let latency_ms = started.elapsed().as_secs_f64() * 1000.0;
        span.attr("mode", mode);
        span.attr("latency_ms", latency_ms);
        if incremental {
            self.counters.epochs_incremental += 1;
        } else {
            self.counters.epochs_full += 1;
        }
        metrics::counter_add("greengen_sched_serve_epochs_total", &[("mode", mode)], 1.0);
        metrics::gauge_set(
            "greengen_sched_serve_queue_depth",
            &[("queue", "samples")],
            sample_depth as f64,
        );
        metrics::gauge_set(
            "greengen_sched_serve_queue_depth",
            &[("queue", "control")],
            control_depth as f64,
        );
        metrics::observe_ms("greengen_sched_serve_epoch_ms", &[], latency_ms);
        writeln!(
            status,
            "# serve epoch={} mode={} queued={} latency_ms={:.3} deadline_ms={}",
            self.epoch, mode, queued, latency_ms, self.config.deadline_ms
        )?;

        self.last_t = t;
        self.epoch += 1;
        Ok(())
    }

    /// End-of-stream: if placement requests are still buffered, run one
    /// final synthetic epoch (one simulated hour past the last tick) so
    /// every request gets a plan, then emit the summary line.
    fn finish(&mut self, out: &mut dyn Write, status: &mut dyn Write) -> Result<ServeSummary> {
        let pending = self
            .control
            .iter()
            .any(|e| matches!(e, Event::Request { .. }));
        if pending {
            let t = self.last_t + 3600.0;
            self.epoch_tick(t, out, status)?;
        }
        let summary = ServeSummary {
            epochs: self.epoch,
            epochs_full: self.counters.epochs_full,
            epochs_incremental: self.counters.epochs_incremental,
            events: self.counters.events,
            responses: self.counters.responses,
            dropped_samples: self.samples.dropped(),
            dropped_control: self.control.dropped(),
            skipped_malformed: self.counters.malformed,
            skipped_unknown_type: self.counters.unknown_type,
            skipped_unknown_name: self.counters.unknown_name,
            skipped_stale: self.counters.stale,
            shutdown: self.shutdown,
        };
        writeln!(out, "{}", jsonio::to_string(&summary.to_json()))?;
        Ok(summary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenarios;
    use crate::pipeline::PipelineConfig;
    use std::io::Cursor;

    fn run_script(script: &str, config: ServeConfig) -> (String, ServeSummary) {
        let scenario = scenarios::scenario(1).unwrap();
        let pipeline = GeneratorPipeline::new(PipelineConfig::default());
        let mut daemon = Daemon::new(&scenario, pipeline, config);
        let mut input = Cursor::new(script.as_bytes().to_vec());
        let mut out = Vec::new();
        let mut status = Vec::new();
        let summary = daemon.run(&mut input, &mut out, &mut status).unwrap();
        (String::from_utf8(out).unwrap(), summary)
    }

    const SCRIPT: &str = concat!(
        r#"{"type":"metric_energy","t":3600,"service":"frontend","flavour":"large","joules":252000}"#,
        "\n",
        r#"{"type":"carbon","region":"FR","intensity":40}"#,
        "\n",
        r#"{"type":"tick","t":3600}"#,
        "\n",
        r#"{"type":"request","id":"r1","kind":"plan"}"#,
        "\n",
        r#"{"type":"tick","t":7200}"#,
        "\n",
        r#"{"type":"shutdown"}"#,
        "\n",
    );

    #[test]
    fn script_produces_epochs_responses_and_a_summary() {
        let (out, summary) = run_script(SCRIPT, ServeConfig::default());
        let lines: Vec<&str> = out.lines().collect();
        // 2 epochs + 1 plan response + summary
        assert_eq!(lines.len(), 4, "stdout: {out}");
        let first = jsonio::parse(lines[0]).unwrap();
        assert_eq!(first.str_field("type").unwrap(), "epoch");
        assert_eq!(first.str_field("mode").unwrap(), "full");
        // every epoch line certifies its plan
        for line in &lines[..2] {
            let v = jsonio::parse(line).unwrap();
            let lb = v.f64_field("lower_bound").unwrap();
            let gap = v.f64_field("gap").unwrap();
            assert!(lb.is_finite(), "lower_bound {lb}");
            assert!(gap.is_finite() && gap >= -1e-9, "gap {gap}");
        }
        let plan = jsonio::parse(lines[2]).unwrap();
        assert_eq!(plan.str_field("type").unwrap(), "plan");
        assert_eq!(plan.str_field("id").unwrap(), "r1");
        assert_eq!(summary.epochs, 2);
        assert_eq!(summary.responses, 1);
        assert!(summary.shutdown);
        assert_eq!(summary.skipped_malformed, 0);
    }

    #[test]
    fn same_script_same_seed_is_byte_identical() {
        let (a, _) = run_script(SCRIPT, ServeConfig::default());
        let (b, _) = run_script(SCRIPT, ServeConfig::default());
        assert_eq!(a, b);
    }

    #[test]
    fn faults_are_counted_not_fatal() {
        let script = concat!(
            "this is not json\n",
            r#"{"type":"warp_drive","t":1}"#,
            "\n",
            r#"{"type":"metric_energy","t":3600,"service":"nosuch","flavour":"tiny","joules":1}"#,
            "\n",
            r#"{"type":"carbon","region":"XX","intensity":1}"#,
            "\n",
            r#"{"type":"tick","t":3600}"#,
            "\n",
            r#"{"type":"tick","t":3600}"#,
            "\n",
        );
        let (_, summary) = run_script(script, ServeConfig::default());
        assert_eq!(summary.skipped_malformed, 1);
        assert_eq!(summary.skipped_unknown_type, 1);
        assert_eq!(summary.skipped_unknown_name, 2);
        assert_eq!(summary.skipped_stale, 1);
        assert_eq!(summary.epochs, 1);
        assert!(!summary.shutdown); // ended on EOF
    }

    #[test]
    fn eof_with_pending_request_still_answers() {
        let script = concat!(
            r#"{"type":"request","id":"late","kind":"plan"}"#,
            "\n",
        );
        let (out, summary) = run_script(script, ServeConfig::default());
        assert_eq!(summary.responses, 1);
        assert_eq!(summary.epochs, 1);
        let plan_line = out.lines().find(|l| l.contains("\"late\"")).unwrap();
        let v = jsonio::parse(plan_line).unwrap();
        assert_eq!(v.str_field("type").unwrap(), "plan");
    }

    #[test]
    fn high_water_degrades_to_incremental() {
        let mut script = String::new();
        for i in 0..8 {
            script.push_str(&format!(
                "{{\"type\":\"metric_energy\",\"t\":{},\"service\":\"frontend\",\"flavour\":\"large\",\"joules\":252000}}\n",
                600 * (i + 1)
            ));
        }
        script.push_str("{\"type\":\"tick\",\"t\":7200}\n");
        let config = ServeConfig {
            queue: 4,
            high_water: 2,
            ..ServeConfig::default()
        };
        let (out, summary) = run_script(&script, config);
        assert_eq!(summary.epochs_incremental, 1);
        assert_eq!(summary.epochs_full, 0);
        // the 4-deep ring shed the 4 oldest samples
        assert_eq!(summary.dropped_samples, 4);
        let epoch = jsonio::parse(out.lines().next().unwrap()).unwrap();
        assert_eq!(epoch.str_field("mode").unwrap(), "incremental");
        assert_eq!(epoch.f64_field("queued").unwrap(), 4.0);
    }

    #[test]
    fn budgets_scale_and_clamp() {
        assert_eq!(budgets(0), (20_000, 12, 4_000, 4));
        let (a, l, i, r) = budgets(1);
        assert_eq!((a, l, i, r), (512, 2, 256, 1));
        let (a, l, i, r) = budgets(100);
        assert_eq!((a, l, i, r), (4_000, 6, 1_000, 1));
        assert_eq!(budgets(10_000), (20_000, 12, 4_000, 4));
    }
}
