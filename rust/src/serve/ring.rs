//! Bounded drop-oldest ring buffer — the daemon's backpressure policy.
//!
//! The serve loop must keep up with an arbitrarily hot event stream
//! without unbounded memory growth, so ingest queues are fixed-capacity
//! FIFOs that **drop the oldest** buffered element on overflow: under
//! sustained overload the daemon schedules against the freshest window
//! of observations rather than an ever-older backlog. Every drop is
//! counted (and exported through the `obs` metrics layer by the daemon)
//! so load shedding is observable, never silent.

use std::collections::VecDeque;

/// A fixed-capacity FIFO that evicts the oldest element on overflow.
#[derive(Debug, Clone)]
pub struct Ring<T> {
    buf: VecDeque<T>,
    cap: usize,
    dropped: u64,
}

impl<T> Ring<T> {
    /// Create a ring holding at most `cap` elements (clamped to ≥ 1).
    pub fn new(cap: usize) -> Ring<T> {
        Ring {
            buf: VecDeque::new(),
            cap: cap.max(1),
            dropped: 0,
        }
    }

    /// Append an element, evicting (and returning) the oldest buffered
    /// element when the ring is full. Eviction bumps [`Ring::dropped`].
    pub fn push(&mut self, item: T) -> Option<T> {
        let evicted = if self.buf.len() >= self.cap {
            self.dropped += 1;
            self.buf.pop_front()
        } else {
            None
        };
        self.buf.push_back(item);
        evicted
    }

    /// Remove and return every buffered element, oldest first.
    pub fn drain(&mut self) -> Vec<T> {
        self.buf.drain(..).collect()
    }

    /// Iterate the buffered elements, oldest first, without consuming.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.buf.iter()
    }

    /// Number of elements currently buffered.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Maximum number of buffered elements.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Lifetime count of elements evicted by overflow.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_is_preserved() {
        let mut r = Ring::new(4);
        for i in 0..4 {
            assert!(r.push(i).is_none());
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.drain(), vec![0, 1, 2, 3]);
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn overflow_drops_the_oldest_and_counts() {
        let mut r = Ring::new(2);
        assert!(r.push(1).is_none());
        assert!(r.push(2).is_none());
        assert_eq!(r.push(3), Some(1));
        assert_eq!(r.push(4), Some(2));
        assert_eq!(r.dropped(), 2);
        assert_eq!(r.drain(), vec![3, 4]);
        // drain resets contents but not the lifetime drop counter
        assert_eq!(r.dropped(), 2);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut r = Ring::new(0);
        assert_eq!(r.capacity(), 1);
        assert!(r.push("a").is_none());
        assert_eq!(r.push("b"), Some("a"));
        assert_eq!(r.iter().collect::<Vec<_>>(), vec![&"b"]);
    }
}
