//! Energy Estimator (§4.1): derives computation (Eq. 1) and communication
//! (Eq. 2) energy profiles from the monitoring store and enriches the
//! Application Description with them.
//!
//! The profiles are hardware-agnostic statistical estimates over the
//! observation history (the paper deliberately avoids per-node profiling —
//! see §4.1's closing discussion).
//!
//! # Columnar + streaming evaluation
//!
//! Both entry points read the store's interned per-series columns
//! directly ([`MetricStore::energy_series`] /
//! [`MetricStore::traffic_series`]) — no merged sample vector is ever
//! materialized and no per-sample `String` is cloned. Because a
//! [`Summary`] is accumulated per series, and samples of one series
//! appear in identical relative order in the columns and in the old
//! merged scan, the resulting summaries are **bit-identical** to the
//! historical whole-store implementation.
//!
//! [`EnergyEstimator::estimate_incremental`] goes further: a series the
//! store reports untouched reuses its previous summary verbatim, and a
//! touched series whose *prefix* is intact (appends only —
//! [`crate::monitoring::EnergySeries::prefix_rev`]` <= since`) extends
//! the previous summary by observing just the suffix of new samples.
//! `Summary::observe` is sequential accumulation, so prefix-summary +
//! suffix replay performs exactly the operation sequence of a full
//! rescan — identity, not approximation, the same contract as
//! `constraints/incremental.rs`. Out-of-order inserts, compaction, or a
//! finite (sliding) lookback void the prefix and fall back to the exact
//! rescan of the affected series (or, for finite lookback, of the whole
//! window).

use super::comm_model::CommEnergyModel;
use crate::model::Application;
use crate::model::EnergyProfile;
use crate::monitoring::metrics::{gb_from_bytes, kwh_from_joules};
use crate::monitoring::store::{EnergySeries, TrafficSeries};
use crate::monitoring::MetricStore;
use crate::util::Summary;
use std::collections::HashMap;

/// Estimator configuration.
#[derive(Debug, Clone, Copy)]
pub struct EstimatorConfig {
    /// Only samples in `(horizon - lookback, horizon]` are used.
    /// `f64::INFINITY` (default) means "use the whole history".
    pub lookback: f64,
    pub comm_model: CommEnergyModel,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        EstimatorConfig {
            lookback: f64::INFINITY,
            comm_model: CommEnergyModel::default(),
        }
    }
}

/// Detailed estimation output: per-(service,flavour) and per-link summary
/// statistics, later folded into the Knowledge Base (SK / IK).
#[derive(Debug, Default, Clone)]
pub struct EstimationReport {
    /// (service, flavour) -> kWh summary across windows.
    pub computation: HashMap<(String, String), Summary>,
    /// (from, from_flavour, to) -> kWh summary across windows (Eq. 13
    /// applied per window).
    pub communication: HashMap<(String, String, String), Summary>,
}

/// The Energy Estimator.
pub struct EnergyEstimator {
    pub config: EstimatorConfig,
}

impl Default for EnergyEstimator {
    fn default() -> Self {
        EnergyEstimator {
            config: EstimatorConfig::default(),
        }
    }
}

/// Summarize one energy series' window `range` (kWh per window, Eq. 1).
fn scan_energy(series: &EnergySeries, range: std::ops::Range<usize>) -> Summary {
    let mut summary = Summary::default();
    for i in range {
        summary.observe(kwh_from_joules(series.joules()[i]));
    }
    summary
}

/// Summarize one traffic series' window `range` (Eq. 13 per window).
fn scan_traffic(series: &TrafficSeries, range: std::ops::Range<usize>, k: CommEnergyModel) -> Summary {
    let mut summary = Summary::default();
    for i in range {
        summary.observe(k.kwh_for_gb(gb_from_bytes(series.bytes()[i])));
    }
    summary
}

impl EnergyEstimator {
    pub fn new(config: EstimatorConfig) -> Self {
        EnergyEstimator { config }
    }

    /// Compute profiles from `store` and enrich `app` in place:
    /// * every observed flavour gets `energy = mean kWh per window` (Eq. 1);
    /// * every observed link gets per-source-flavour communication energy
    ///   (Eq. 2 with Eq. 13 converting traffic to kWh).
    ///
    /// Returns the detailed report (min/max/mean summaries) for KB
    /// enrichment. Flavours never observed keep their previous profile —
    /// adaptivity must not erase knowledge (§3 "preserving and refining
    /// knowledge acquired in previous iterations").
    pub fn estimate(&self, app: &mut Application, store: &MetricStore) -> EstimationReport {
        let horizon = store.horizon();
        let from_t = if self.config.lookback.is_finite() {
            horizon - self.config.lookback
        } else {
            f64::NEG_INFINITY
        };

        let mut report = EstimationReport::default();

        // --- Eq. 1: computation profiles --------------------------------
        for id in store.energy_series_ids() {
            let series = match store.energy_series(id) {
                Some(s) => s,
                None => continue,
            };
            let window = series.window(from_t, horizon);
            if window.is_empty() {
                continue;
            }
            let summary = scan_energy(series, window);
            if let Some((service, flavour)) = store.energy_series_key(id) {
                report
                    .computation
                    .insert((service.to_string(), flavour.to_string()), summary);
            }
        }

        // --- Eq. 2 + Eq. 13: communication profiles ---------------------
        let k = self.config.comm_model;
        for id in store.traffic_series_ids() {
            let series = match store.traffic_series(id) {
                Some(s) => s,
                None => continue,
            };
            let window = series.window(from_t, horizon);
            if window.is_empty() {
                continue;
            }
            let summary = scan_traffic(series, window, k);
            if let Some((from, flavour, to)) = store.traffic_series_key(id) {
                report.communication.insert(
                    (from.to_string(), flavour.to_string(), to.to_string()),
                    summary,
                );
            }
        }

        self.apply(app, &report);
        report
    }

    /// Incremental variant of [`EnergyEstimator::estimate`] for the
    /// adaptive loop's change-stamped epochs. `prev` must be the report
    /// computed when the store stood at revision `since`. Per series:
    ///
    /// * untouched since `since` → its `prev` summary is reused verbatim
    ///   (an untouched series' whole-history summary cannot change);
    /// * touched with an intact prefix (appends only) → the `prev`
    ///   summary is extended by **streaming** just the new suffix of
    ///   samples, which replays exactly the accumulation a full rescan
    ///   would perform — bit-identical by construction;
    /// * touched with a rewritten prefix (out-of-order insert or
    ///   compaction) → exact per-series rescan.
    ///
    /// With an infinite lookback (the default) the result is exactly
    /// equal to a full [`EnergyEstimator::estimate`]. A finite lookback
    /// slides the observation window every epoch, so the method falls
    /// back to the full pass.
    pub fn estimate_incremental(
        &self,
        app: &mut Application,
        store: &MetricStore,
        prev: &EstimationReport,
        since: u64,
    ) -> EstimationReport {
        if self.config.lookback.is_finite() {
            return self.estimate(app, store);
        }

        let mut report = EstimationReport::default();

        for id in store.energy_series_ids() {
            let series = match store.energy_series(id) {
                Some(s) => s,
                None => continue,
            };
            if series.is_empty() {
                continue;
            }
            let key = match store.energy_series_key(id) {
                Some((service, flavour)) => (service.to_string(), flavour.to_string()),
                None => continue,
            };
            let prev_entry = prev.computation.get(&key).copied();
            let summary = stream_or_rescan(prev_entry, series.rev(), series.prefix_rev(), since, series.len(), |prefix, lo| {
                let mut s = prefix;
                for i in lo..series.len() {
                    s.observe(kwh_from_joules(series.joules()[i]));
                }
                s
            });
            report.computation.insert(key, summary);
        }

        let k = self.config.comm_model;
        for id in store.traffic_series_ids() {
            let series = match store.traffic_series(id) {
                Some(s) => s,
                None => continue,
            };
            if series.is_empty() {
                continue;
            }
            let key = match store.traffic_series_key(id) {
                Some((from, flavour, to)) => {
                    (from.to_string(), flavour.to_string(), to.to_string())
                }
                None => continue,
            };
            let prev_entry = prev.communication.get(&key).copied();
            let summary = stream_or_rescan(prev_entry, series.rev(), series.prefix_rev(), since, series.len(), |prefix, lo| {
                let mut s = prefix;
                for i in lo..series.len() {
                    s.observe(k.kwh_for_gb(gb_from_bytes(series.bytes()[i])));
                }
                s
            });
            report.communication.insert(key, summary);
        }

        self.apply(app, &report);
        report
    }

    /// Enrich `app` in place from a report's summaries (Eq. 1 computation
    /// profiles, Eq. 2 per-source-flavour communication energies).
    /// Communication entries apply in sorted key order: `link.energy`
    /// grows by push, so a deterministic application order keeps every
    /// downstream consumer (constraint flattening, adapters) independent
    /// of `HashMap` iteration order.
    fn apply(&self, app: &mut Application, report: &EstimationReport) {
        for ((service, flavour), summary) in &report.computation {
            if let Some(svc) = app.service_mut(service) {
                if let Some(fl) = svc.flavour_mut(flavour) {
                    fl.energy = Some(EnergyProfile {
                        kwh: summary.mean(),
                        samples: summary.count,
                    });
                }
            }
        }
        let mut comm_keys: Vec<&(String, String, String)> = report.communication.keys().collect();
        comm_keys.sort();
        for key in comm_keys {
            let (from, flavour, to) = (&key.0, &key.1, &key.2);
            let summary = &report.communication[key];
            if let Some(link) = app.link_mut(from, to) {
                let mean = summary.mean();
                if let Some(slot) = link.energy.iter_mut().find(|(f, _)| f == flavour) {
                    slot.1 = mean;
                } else {
                    link.energy.push((flavour.clone(), mean));
                }
            }
        }
    }
}

/// The streaming decision shared by both kinds: reuse the previous
/// summary when the series is untouched, extend it over the suffix when
/// only appends happened, rescan otherwise. `replay(prefix, lo)` must
/// observe samples `lo..len` onto `prefix` in column order.
fn stream_or_rescan(
    prev: Option<Summary>,
    rev: u64,
    prefix_rev: u64,
    since: u64,
    len: usize,
    replay: impl Fn(Summary, usize) -> Summary,
) -> Summary {
    match prev {
        Some(p) if rev <= since => p,
        Some(p) if prefix_rev <= since && (p.count as usize) <= len => {
            replay(p, p.count as usize)
        }
        _ => replay(Summary::default(), 0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{CommLink, Flavour, Service};
    use crate::monitoring::{EnergySample, TrafficSample};

    fn app() -> Application {
        let mut app = Application::new("demo");
        let mut fe = Service::new("frontend");
        fe.flavours = vec![Flavour::new("large"), Flavour::new("tiny")];
        let mut cart = Service::new("cart");
        cart.flavours = vec![Flavour::new("tiny")];
        app.services = vec![fe, cart];
        app.links = vec![CommLink::new("frontend", "cart")];
        app
    }

    fn store_with(samples: &[(f64, &str, &str, f64)]) -> MetricStore {
        let mut store = MetricStore::new();
        for (t, svc, fl, joules) in samples {
            store.push_energy(EnergySample {
                t: *t,
                service: svc.to_string(),
                flavour: fl.to_string(),
                joules: *joules,
            });
        }
        store
    }

    #[test]
    fn eq1_mean_of_windows() {
        let mut app = app();
        // two windows: 3.6e6 J = 1 kWh and 7.2e6 J = 2 kWh -> mean 1.5 kWh
        let store = store_with(&[
            (3600.0, "frontend", "large", 3.6e6),
            (7200.0, "frontend", "large", 7.2e6),
        ]);
        let report = EnergyEstimator::default().estimate(&mut app, &store);
        let profile = app
            .service("frontend")
            .unwrap()
            .flavour("large")
            .unwrap()
            .energy
            .unwrap();
        assert!((profile.kwh - 1.5).abs() < 1e-12);
        assert_eq!(profile.samples, 2);
        let summary = &report.computation[&("frontend".into(), "large".into())];
        assert_eq!(summary.min, 1.0);
        assert_eq!(summary.max, 2.0);
    }

    #[test]
    fn unobserved_flavour_keeps_previous_profile() {
        let mut app = app();
        app.service_mut("frontend")
            .unwrap()
            .flavour_mut("tiny")
            .unwrap()
            .energy = Some(EnergyProfile { kwh: 0.9, samples: 5 });
        let store = store_with(&[(3600.0, "frontend", "large", 3.6e6)]);
        EnergyEstimator::default().estimate(&mut app, &store);
        let tiny = app.service("frontend").unwrap().flavour("tiny").unwrap();
        assert_eq!(tiny.energy.unwrap().kwh, 0.9);
    }

    #[test]
    fn eq2_communication_profile_via_eq13() {
        let mut app = app();
        let mut store = MetricStore::new();
        for (t, gb) in [(3600.0, 2.0), (7200.0, 4.0)] {
            store.push_traffic(TrafficSample {
                t,
                from: "frontend".into(),
                from_flavour: "large".into(),
                to: "cart".into(),
                requests: 100.0,
                bytes: gb * 1e9,
            });
        }
        let est = EnergyEstimator::default();
        est.estimate(&mut app, &store);
        let link = &app.links[0];
        let kwh = link.energy_for("large").unwrap();
        let expect = est.config.comm_model.kwh_for_gb(3.0); // mean of 2,4 GB
        assert!((kwh - expect).abs() < 1e-12, "{kwh} vs {expect}");
    }

    #[test]
    fn lookback_limits_history() {
        let mut app = app();
        let store = store_with(&[
            (3600.0, "frontend", "large", 3.6e6),  // old: 1 kWh
            (7200.0, "frontend", "large", 10.8e6), // recent: 3 kWh
        ]);
        let est = EnergyEstimator::new(EstimatorConfig {
            lookback: 3600.0, // only the last window
            ..Default::default()
        });
        est.estimate(&mut app, &store);
        let profile = app
            .service("frontend")
            .unwrap()
            .flavour("large")
            .unwrap()
            .energy
            .unwrap();
        assert!((profile.kwh - 3.0).abs() < 1e-12);
        assert_eq!(profile.samples, 1);
    }

    #[test]
    fn incremental_estimate_equals_full() {
        let est = EnergyEstimator::default();
        let mut store = MetricStore::new();
        store.push_energy(EnergySample {
            t: 3600.0,
            service: "frontend".into(),
            flavour: "large".into(),
            joules: 3.6e6,
        });
        store.push_energy(EnergySample {
            t: 3600.0,
            service: "cart".into(),
            flavour: "tiny".into(),
            joules: 1.8e6,
        });
        store.push_traffic(TrafficSample {
            t: 3600.0,
            from: "frontend".into(),
            from_flavour: "large".into(),
            to: "cart".into(),
            requests: 10.0,
            bytes: 2e9,
        });
        let mut app_full = app();
        let prev = est.estimate(&mut app_full, &store);
        let rev = store.revision();

        // only frontend/large receives a new window
        store.push_energy(EnergySample {
            t: 7200.0,
            service: "frontend".into(),
            flavour: "large".into(),
            joules: 7.2e6,
        });

        let mut app_inc = app();
        let inc = est.estimate_incremental(&mut app_inc, &store, &prev, rev);
        let mut app_full2 = app();
        let full = est.estimate(&mut app_full2, &store);
        assert_eq!(inc.computation, full.computation);
        assert_eq!(inc.communication, full.communication);
        // the untouched series entry is the reused one, bit-for-bit
        assert_eq!(
            inc.computation[&("cart".to_string(), "tiny".to_string())],
            prev.computation[&("cart".to_string(), "tiny".to_string())]
        );
        // applied profiles agree too
        assert_eq!(
            app_inc.service("frontend").unwrap().flavour("large").unwrap().energy,
            app_full2.service("frontend").unwrap().flavour("large").unwrap().energy,
        );
    }

    #[test]
    fn incremental_estimate_with_nothing_touched_reuses_report() {
        let est = EnergyEstimator::default();
        let mut store = MetricStore::new();
        store.push_energy(EnergySample {
            t: 3600.0,
            service: "frontend".into(),
            flavour: "large".into(),
            joules: 3.6e6,
        });
        let mut a = app();
        let prev = est.estimate(&mut a, &store);
        let rev = store.revision();
        let mut b = app();
        let inc = est.estimate_incremental(&mut b, &store, &prev, rev);
        assert_eq!(inc.computation, prev.computation);
        assert_eq!(
            b.service("frontend").unwrap().flavour("large").unwrap().energy.unwrap().kwh,
            1.0
        );
    }

    #[test]
    fn streaming_suffix_extension_is_exact() {
        // Many appends onto a touched series: the streamed summary must
        // equal the full rescan bit-for-bit (sum is sequential f64
        // accumulation, so this checks op-sequence identity, not just
        // tolerance).
        let est = EnergyEstimator::default();
        let mut store = MetricStore::new();
        for i in 0..10 {
            store.push_energy(EnergySample {
                t: 3600.0 * (i + 1) as f64,
                service: "frontend".into(),
                flavour: "large".into(),
                joules: 1.7e5 * (i + 1) as f64,
            });
        }
        let mut a = app();
        let prev = est.estimate(&mut a, &store);
        let rev = store.revision();
        for i in 10..23 {
            store.push_energy(EnergySample {
                t: 3600.0 * (i + 1) as f64,
                service: "frontend".into(),
                flavour: "large".into(),
                joules: 3.1e5 * (i + 1) as f64,
            });
        }
        let mut b = app();
        let inc = est.estimate_incremental(&mut b, &store, &prev, rev);
        let mut c = app();
        let full = est.estimate(&mut c, &store);
        let key = ("frontend".to_string(), "large".to_string());
        assert_eq!(inc.computation[&key], full.computation[&key]);
        assert_eq!(inc.computation[&key].sum.to_bits(), full.computation[&key].sum.to_bits());
    }

    #[test]
    fn prefix_rewrite_falls_back_to_rescan() {
        // An out-of-order insert below the watermark voids the prefix;
        // the incremental path must still equal the full pass exactly.
        let est = EnergyEstimator::default();
        let mut store = MetricStore::new();
        for t in [3600.0, 7200.0, 10800.0] {
            store.push_energy(EnergySample {
                t,
                service: "frontend".into(),
                flavour: "large".into(),
                joules: t * 100.0,
            });
        }
        let mut a = app();
        let prev = est.estimate(&mut a, &store);
        let rev = store.revision();
        store.push_energy(EnergySample {
            t: 5400.0, // lands between existing samples
            service: "frontend".into(),
            flavour: "large".into(),
            joules: 9.9e5,
        });
        let mut b = app();
        let inc = est.estimate_incremental(&mut b, &store, &prev, rev);
        let mut c = app();
        let full = est.estimate(&mut c, &store);
        assert_eq!(inc.computation, full.computation);
        // and after compaction (which also voids every prefix)
        store.compact(4000.0);
        let rev2 = store.revision();
        let mut d = app();
        let prev2 = est.estimate_incremental(&mut d, &store, &inc, rev);
        let mut e = app();
        let full2 = est.estimate(&mut e, &store);
        assert_eq!(prev2.computation, full2.computation);
        let _ = rev2;
    }

    #[test]
    fn samples_for_unknown_services_ignored() {
        let mut app = app();
        let store = store_with(&[(3600.0, "ghost", "x", 3.6e6)]);
        let report = EnergyEstimator::default().estimate(&mut app, &store);
        // report still carries the observation (KB may know the service)
        assert_eq!(report.computation.len(), 1);
        // but the application is untouched
        assert!(app
            .service("frontend")
            .unwrap()
            .flavour("large")
            .unwrap()
            .energy
            .is_none());
    }
}
