//! Energy Estimator (§4.1): derives computation (Eq. 1) and communication
//! (Eq. 2) energy profiles from the monitoring store and enriches the
//! Application Description with them.
//!
//! The profiles are hardware-agnostic statistical estimates over the
//! observation history (the paper deliberately avoids per-node profiling —
//! see §4.1's closing discussion).

use super::comm_model::CommEnergyModel;
use crate::model::Application;
use crate::monitoring::MetricStore;
use crate::model::EnergyProfile;
use crate::util::Summary;
use std::collections::HashMap;

/// Estimator configuration.
#[derive(Debug, Clone, Copy)]
pub struct EstimatorConfig {
    /// Only samples in `(horizon - lookback, horizon]` are used.
    /// `f64::INFINITY` (default) means "use the whole history".
    pub lookback: f64,
    pub comm_model: CommEnergyModel,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        EstimatorConfig {
            lookback: f64::INFINITY,
            comm_model: CommEnergyModel::default(),
        }
    }
}

/// Detailed estimation output: per-(service,flavour) and per-link summary
/// statistics, later folded into the Knowledge Base (SK / IK).
#[derive(Debug, Default, Clone)]
pub struct EstimationReport {
    /// (service, flavour) -> kWh summary across windows.
    pub computation: HashMap<(String, String), Summary>,
    /// (from, from_flavour, to) -> kWh summary across windows (Eq. 13
    /// applied per window).
    pub communication: HashMap<(String, String, String), Summary>,
}

/// The Energy Estimator.
pub struct EnergyEstimator {
    pub config: EstimatorConfig,
}

impl Default for EnergyEstimator {
    fn default() -> Self {
        EnergyEstimator {
            config: EstimatorConfig::default(),
        }
    }
}

impl EnergyEstimator {
    pub fn new(config: EstimatorConfig) -> Self {
        EnergyEstimator { config }
    }

    /// Compute profiles from `store` and enrich `app` in place:
    /// * every observed flavour gets `energy = mean kWh per window` (Eq. 1);
    /// * every observed link gets per-source-flavour communication energy
    ///   (Eq. 2 with Eq. 13 converting traffic to kWh).
    ///
    /// Returns the detailed report (min/max/mean summaries) for KB
    /// enrichment. Flavours never observed keep their previous profile —
    /// adaptivity must not erase knowledge (§3 "preserving and refining
    /// knowledge acquired in previous iterations").
    pub fn estimate(&self, app: &mut Application, store: &MetricStore) -> EstimationReport {
        let horizon = store.horizon();
        let from_t = if self.config.lookback.is_finite() {
            horizon - self.config.lookback
        } else {
            f64::NEG_INFINITY
        };

        let mut report = EstimationReport::default();

        // --- Eq. 1: computation profiles --------------------------------
        for s in store.energy_range(from_t, horizon) {
            report
                .computation
                .entry((s.service.clone(), s.flavour.clone()))
                .or_default()
                .observe(s.kwh());
        }

        // --- Eq. 2 + Eq. 13: communication profiles ---------------------
        let k = self.config.comm_model;
        for s in store.traffic_range(from_t, horizon) {
            report
                .communication
                .entry((s.from.clone(), s.from_flavour.clone(), s.to.clone()))
                .or_default()
                .observe(k.kwh_for_gb(s.gb()));
        }

        self.apply(app, &report);
        report
    }

    /// Incremental variant of [`EnergyEstimator::estimate`] for the
    /// adaptive loop's change-stamped epochs: summaries are recomputed
    /// only for the series the store reports touched since revision
    /// `since` ([`MetricStore::energy_touched_since`] /
    /// [`MetricStore::traffic_touched_since`]); every other series reuses
    /// its entry from `prev` unchanged. With an infinite lookback (the
    /// default) this is exactly equal to a full [`EnergyEstimator::estimate`]
    /// — an untouched series' whole-history summary cannot change. A
    /// finite lookback slides the observation window every epoch, so the
    /// method falls back to the full pass.
    pub fn estimate_incremental(
        &self,
        app: &mut Application,
        store: &MetricStore,
        prev: &EstimationReport,
        since: u64,
    ) -> EstimationReport {
        if self.config.lookback.is_finite() {
            return self.estimate(app, store);
        }
        let touched_e_keys = store.energy_touched_since(since);
        let touched_t_keys = store.traffic_touched_since(since);
        // everything changed (the steady-state of a simulator that feeds
        // every series every window): the full pass does strictly less
        // work than a filtered scan — take it directly
        if touched_e_keys.len() == store.energy_series_count()
            && touched_t_keys.len() == store.traffic_series_count()
        {
            return self.estimate(app, store);
        }
        let touched_e: std::collections::HashSet<(&str, &str)> = touched_e_keys
            .into_iter()
            .map(|(s, f)| (s.as_str(), f.as_str()))
            .collect();
        let touched_t: std::collections::HashSet<(&str, &str, &str)> = touched_t_keys
            .into_iter()
            .map(|(a, f, b)| (a.as_str(), f.as_str(), b.as_str()))
            .collect();

        let mut report = EstimationReport::default();
        for (key, summary) in &prev.computation {
            if !touched_e.contains(&(key.0.as_str(), key.1.as_str())) {
                report.computation.insert(key.clone(), *summary);
            }
        }
        for (key, summary) in &prev.communication {
            if !touched_t.contains(&(key.0.as_str(), key.1.as_str(), key.2.as_str())) {
                report.communication.insert(key.clone(), *summary);
            }
        }

        let horizon = store.horizon();
        if !touched_e.is_empty() {
            for s in store.energy_range(f64::NEG_INFINITY, horizon) {
                if touched_e.contains(&(s.service.as_str(), s.flavour.as_str())) {
                    report
                        .computation
                        .entry((s.service.clone(), s.flavour.clone()))
                        .or_default()
                        .observe(s.kwh());
                }
            }
        }
        if !touched_t.is_empty() {
            let k = self.config.comm_model;
            for s in store.traffic_range(f64::NEG_INFINITY, horizon) {
                if touched_t.contains(&(
                    s.from.as_str(),
                    s.from_flavour.as_str(),
                    s.to.as_str(),
                )) {
                    report
                        .communication
                        .entry((s.from.clone(), s.from_flavour.clone(), s.to.clone()))
                        .or_default()
                        .observe(k.kwh_for_gb(s.gb()));
                }
            }
        }

        self.apply(app, &report);
        report
    }

    /// Enrich `app` in place from a report's summaries (Eq. 1 computation
    /// profiles, Eq. 2 per-source-flavour communication energies).
    fn apply(&self, app: &mut Application, report: &EstimationReport) {
        for ((service, flavour), summary) in &report.computation {
            if let Some(svc) = app.service_mut(service) {
                if let Some(fl) = svc.flavour_mut(flavour) {
                    fl.energy = Some(EnergyProfile {
                        kwh: summary.mean(),
                        samples: summary.count,
                    });
                }
            }
        }
        for ((from, flavour, to), summary) in &report.communication {
            if let Some(link) = app.link_mut(from, to) {
                let mean = summary.mean();
                if let Some(slot) = link.energy.iter_mut().find(|(f, _)| f == flavour) {
                    slot.1 = mean;
                } else {
                    link.energy.push((flavour.clone(), mean));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{CommLink, Flavour, Service};
    use crate::monitoring::{EnergySample, TrafficSample};

    fn app() -> Application {
        let mut app = Application::new("demo");
        let mut fe = Service::new("frontend");
        fe.flavours = vec![Flavour::new("large"), Flavour::new("tiny")];
        let mut cart = Service::new("cart");
        cart.flavours = vec![Flavour::new("tiny")];
        app.services = vec![fe, cart];
        app.links = vec![CommLink::new("frontend", "cart")];
        app
    }

    fn store_with(samples: &[(f64, &str, &str, f64)]) -> MetricStore {
        let mut store = MetricStore::new();
        for (t, svc, fl, joules) in samples {
            store.push_energy(EnergySample {
                t: *t,
                service: svc.to_string(),
                flavour: fl.to_string(),
                joules: *joules,
            });
        }
        store
    }

    #[test]
    fn eq1_mean_of_windows() {
        let mut app = app();
        // two windows: 3.6e6 J = 1 kWh and 7.2e6 J = 2 kWh -> mean 1.5 kWh
        let store = store_with(&[
            (3600.0, "frontend", "large", 3.6e6),
            (7200.0, "frontend", "large", 7.2e6),
        ]);
        let report = EnergyEstimator::default().estimate(&mut app, &store);
        let profile = app
            .service("frontend")
            .unwrap()
            .flavour("large")
            .unwrap()
            .energy
            .unwrap();
        assert!((profile.kwh - 1.5).abs() < 1e-12);
        assert_eq!(profile.samples, 2);
        let summary = &report.computation[&("frontend".into(), "large".into())];
        assert_eq!(summary.min, 1.0);
        assert_eq!(summary.max, 2.0);
    }

    #[test]
    fn unobserved_flavour_keeps_previous_profile() {
        let mut app = app();
        app.service_mut("frontend")
            .unwrap()
            .flavour_mut("tiny")
            .unwrap()
            .energy = Some(EnergyProfile { kwh: 0.9, samples: 5 });
        let store = store_with(&[(3600.0, "frontend", "large", 3.6e6)]);
        EnergyEstimator::default().estimate(&mut app, &store);
        let tiny = app.service("frontend").unwrap().flavour("tiny").unwrap();
        assert_eq!(tiny.energy.unwrap().kwh, 0.9);
    }

    #[test]
    fn eq2_communication_profile_via_eq13() {
        let mut app = app();
        let mut store = MetricStore::new();
        for (t, gb) in [(3600.0, 2.0), (7200.0, 4.0)] {
            store.push_traffic(TrafficSample {
                t,
                from: "frontend".into(),
                from_flavour: "large".into(),
                to: "cart".into(),
                requests: 100.0,
                bytes: gb * 1e9,
            });
        }
        let est = EnergyEstimator::default();
        est.estimate(&mut app, &store);
        let link = &app.links[0];
        let kwh = link.energy_for("large").unwrap();
        let expect = est.config.comm_model.kwh_for_gb(3.0); // mean of 2,4 GB
        assert!((kwh - expect).abs() < 1e-12, "{kwh} vs {expect}");
    }

    #[test]
    fn lookback_limits_history() {
        let mut app = app();
        let store = store_with(&[
            (3600.0, "frontend", "large", 3.6e6),  // old: 1 kWh
            (7200.0, "frontend", "large", 10.8e6), // recent: 3 kWh
        ]);
        let est = EnergyEstimator::new(EstimatorConfig {
            lookback: 3600.0, // only the last window
            ..Default::default()
        });
        est.estimate(&mut app, &store);
        let profile = app
            .service("frontend")
            .unwrap()
            .flavour("large")
            .unwrap()
            .energy
            .unwrap();
        assert!((profile.kwh - 3.0).abs() < 1e-12);
        assert_eq!(profile.samples, 1);
    }

    #[test]
    fn incremental_estimate_equals_full() {
        let est = EnergyEstimator::default();
        let mut store = MetricStore::new();
        store.push_energy(EnergySample {
            t: 3600.0,
            service: "frontend".into(),
            flavour: "large".into(),
            joules: 3.6e6,
        });
        store.push_energy(EnergySample {
            t: 3600.0,
            service: "cart".into(),
            flavour: "tiny".into(),
            joules: 1.8e6,
        });
        store.push_traffic(TrafficSample {
            t: 3600.0,
            from: "frontend".into(),
            from_flavour: "large".into(),
            to: "cart".into(),
            requests: 10.0,
            bytes: 2e9,
        });
        let mut app_full = app();
        let prev = est.estimate(&mut app_full, &store);
        let rev = store.revision();

        // only frontend/large receives a new window
        store.push_energy(EnergySample {
            t: 7200.0,
            service: "frontend".into(),
            flavour: "large".into(),
            joules: 7.2e6,
        });

        let mut app_inc = app();
        let inc = est.estimate_incremental(&mut app_inc, &store, &prev, rev);
        let mut app_full2 = app();
        let full = est.estimate(&mut app_full2, &store);
        assert_eq!(inc.computation, full.computation);
        assert_eq!(inc.communication, full.communication);
        // the untouched series entry is the reused one, bit-for-bit
        assert_eq!(
            inc.computation[&("cart".to_string(), "tiny".to_string())],
            prev.computation[&("cart".to_string(), "tiny".to_string())]
        );
        // applied profiles agree too
        assert_eq!(
            app_inc.service("frontend").unwrap().flavour("large").unwrap().energy,
            app_full2.service("frontend").unwrap().flavour("large").unwrap().energy,
        );
    }

    #[test]
    fn incremental_estimate_with_nothing_touched_reuses_report() {
        let est = EnergyEstimator::default();
        let mut store = MetricStore::new();
        store.push_energy(EnergySample {
            t: 3600.0,
            service: "frontend".into(),
            flavour: "large".into(),
            joules: 3.6e6,
        });
        let mut a = app();
        let prev = est.estimate(&mut a, &store);
        let rev = store.revision();
        let mut b = app();
        let inc = est.estimate_incremental(&mut b, &store, &prev, rev);
        assert_eq!(inc.computation, prev.computation);
        assert_eq!(
            b.service("frontend").unwrap().flavour("large").unwrap().energy.unwrap().kwh,
            1.0
        );
    }

    #[test]
    fn samples_for_unknown_services_ignored() {
        let mut app = app();
        let store = store_with(&[(3600.0, "ghost", "x", 3.6e6)]);
        let report = EnergyEstimator::default().estimate(&mut app, &store);
        // report still carries the observation (KB may know the service)
        assert_eq!(report.computation.len(), 1);
        // but the application is untouched
        assert!(app
            .service("frontend")
            .unwrap()
            .flavour("large")
            .unwrap()
            .energy
            .is_none());
    }
}
