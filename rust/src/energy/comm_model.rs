//! Communication energy model (Eq. 13):
//!
//! ```text
//! kWh = requestVolume · requestSize · k
//! ```
//!
//! where `k` is the transmission-network electricity intensity in kWh/GB.
//! The paper adopts the Aslan et al. (2018) estimate — 0.06 kWh/GB in
//! 2015, halving every two years — extrapolated to 2025. We implement the
//! same extrapolation:
//!
//! ```text
//! k(year) = 0.06 * 0.5^((year - 2015) / 2)
//! ```
//!
//! giving k(2025) ≈ 0.001875 kWh/GB.

/// Aslan et al. 2015 baseline (kWh/GB).
pub const K_2015: f64 = 0.06;

/// Network electricity intensity extrapolated to `year` (kWh/GB).
pub fn network_intensity_kwh_per_gb(year: u32) -> f64 {
    K_2015 * 0.5_f64.powf((year as f64 - 2015.0) / 2.0)
}

/// The communication energy model used by the Energy Estimator.
#[derive(Debug, Clone, Copy)]
pub struct CommEnergyModel {
    /// kWh per GB transferred.
    pub k: f64,
}

impl Default for CommEnergyModel {
    fn default() -> Self {
        // The paper uses the projected 2025 value.
        CommEnergyModel {
            k: network_intensity_kwh_per_gb(2025),
        }
    }
}

impl CommEnergyModel {
    pub fn for_year(year: u32) -> Self {
        CommEnergyModel {
            k: network_intensity_kwh_per_gb(year),
        }
    }

    /// Eq. 13 — energy (kWh) of transferring `gb` gigabytes.
    pub fn kwh_for_gb(&self, gb: f64) -> f64 {
        gb * self.k
    }

    /// Eq. 13 in the paper's original variables: request volume × request
    /// size (GB) × k.
    pub fn kwh(&self, request_volume: f64, request_size_gb: f64) -> f64 {
        request_volume * request_size_gb * self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extrapolation_matches_trend() {
        assert!((network_intensity_kwh_per_gb(2015) - 0.06).abs() < 1e-12);
        assert!((network_intensity_kwh_per_gb(2017) - 0.03).abs() < 1e-12);
        let k2025 = network_intensity_kwh_per_gb(2025);
        assert!((k2025 - 0.001875).abs() < 1e-9, "k2025 {k2025}");
    }

    #[test]
    fn eq13_forms_agree() {
        let m = CommEnergyModel::default();
        // 100 requests x 0.5 GB each
        let a = m.kwh(100.0, 0.5);
        let b = m.kwh_for_gb(50.0);
        assert!((a - b).abs() < 1e-15);
        assert!(a > 0.0);
    }

    #[test]
    fn default_is_2025() {
        let m = CommEnergyModel::default();
        assert!((m.k - network_intensity_kwh_per_gb(2025)).abs() < 1e-15);
    }
}
