//! Energy Estimator (§4.1): computation and communication energy profiles
//! learned from monitoring data.
//!
//! * [`comm_model`] — the Aslan et al. transmission-energy model (Eq. 13)
//!   with the network electricity intensity `k` extrapolated to a target
//!   year.
//! * [`estimator`] — Eq. 1 (computation profile) and Eq. 2 (communication
//!   profile), enriching the Application Description.

pub mod comm_model;
pub mod estimator;

pub use comm_model::{network_intensity_kwh_per_gb, CommEnergyModel};
pub use estimator::{EnergyEstimator, EstimatorConfig};
