//! Integration tests for the `continuum` subsystem: partition soundness,
//! sharded-vs-monolithic objective bounds (property-tested on random
//! 2-zone instances), and exact parity with branch-and-bound on tiny
//! instances.

use greengen::constraints::{Constraint, ConstraintGenerator, GeneratorConfig};
use greengen::continuum::{ShardedScheduler, ZonePartitioner};
use greengen::model::{Application, Infrastructure};
use greengen::runtime::NativeBackend;
use greengen::scheduler::{
    BranchAndBoundScheduler, GreedyScheduler, Objective, Problem, Scheduler,
};
use greengen::simulate;
use greengen::util::proptest::check;
use greengen::util::Rng;

/// Random instance with generated-and-weighted green constraints.
fn instance(
    rng: &mut Rng,
    services: usize,
    nodes: usize,
    capacity_scale: f64,
) -> (Application, Infrastructure, Vec<Constraint>) {
    let app = simulate::random_application(rng, services);
    let mut infra = simulate::random_infrastructure(rng, nodes);
    for n in &mut infra.nodes {
        n.capabilities.cpu *= capacity_scale;
        n.capabilities.ram_gb *= capacity_scale;
    }
    let backend = NativeBackend;
    let mut constraints = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.7,
            use_prolog: false,
        })
        .generate(&app, &infra)
        .unwrap()
        .constraints;
    for (i, c) in constraints.iter_mut().enumerate() {
        c.weight = 0.1 + 0.05 * (i % 10) as f64;
    }
    (app, infra, constraints)
}

fn assert_feasible(problem: &Problem, plan: &greengen::model::DeploymentPlan) {
    if let Err(e) = greengen::scheduler::check_feasible(problem, plan) {
        panic!("infeasible plan: {e}");
    }
}

#[test]
fn property_sharded_feasible_and_bounded_gap_on_2_zone_instances() {
    check("sharded 2-zone feasibility + gap", 32, |rng| {
        let services = 16 + rng.below(17); // 16..=32
        let nodes = 6 + rng.below(9); // 6..=14
        // 2x capacity headroom: the property is about plan quality, not
        // about knife-edge feasibility (both solvers are heuristics there)
        let (app, infra, constraints) = instance(rng, services, nodes, 2.0);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        let greedy = GreedyScheduler::default().schedule(&problem).unwrap();
        let sharded_solver = ShardedScheduler {
            partitioner: ZonePartitioner::with_zones(2),
            exact_services: 0,
            exact_nodes: 0,
            monolithic_below: 0,
            ..ShardedScheduler::default()
        };
        let (plan, stats) = sharded_solver.schedule_with_stats(&problem).unwrap();
        assert_eq!(stats.mode, "sharded");
        assert_eq!(stats.zones, 2);
        assert_feasible(&problem, &plan);

        // bounded objective gap vs the monolithic baseline. This is a
        // coarse regression tripwire, not a tight guarantee: sharding may
        // cut cross-zone affinities, but partition + repair must keep the
        // damage bounded.
        let g = problem.objective_value(&problem.to_assignment(&greedy).unwrap());
        let s = problem.objective_value(&problem.to_assignment(&plan).unwrap());
        assert!(
            s <= 2.0 * g + 30.0,
            "sharded objective {s:.2} vs greedy {g:.2} ({services} svc x {nodes} nodes)"
        );
    });
}

#[test]
fn exact_parity_with_branch_and_bound_on_tiny_instances() {
    let mut rng = Rng::new(0x7A217);
    for _ in 0..5 {
        let (app, infra, constraints) = instance(&mut rng, 5, 4, 1.0);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        let sharded = ShardedScheduler::default();
        let exact = BranchAndBoundScheduler::default().schedule(&problem);
        let via_sharded = sharded.schedule_with_stats(&problem);
        match (exact, via_sharded) {
            (Ok(e), Ok((s, stats))) => {
                assert_eq!(stats.mode, "exact-delegate");
                // the delegate runs the very same solver: plans identical
                assert_eq!(e, s);
                let ve = problem.objective_value(&problem.to_assignment(&e).unwrap());
                let vs = problem.objective_value(&problem.to_assignment(&s).unwrap());
                assert!((ve - vs).abs() < 1e-9);
            }
            (Err(_), Err(_)) => {}
            (a, b) => panic!(
                "feasibility disagreement: exact {:?} vs sharded {:?}",
                a.is_ok(),
                b.is_ok()
            ),
        }
    }
}

#[test]
fn partition_covers_everything_exactly_once_on_all_topologies() {
    for topo in simulate::Topology::ALL {
        let spec = simulate::TopologySpec::new(topo, 48, 96)
            .with_zones(4)
            .with_seed(0xC0FE);
        let (app, infra) = simulate::topology::generate(&spec);
        let partition = ZonePartitioner::default().partition(&app, &infra, &[]);
        let mut node_seen = vec![0usize; infra.nodes.len()];
        let mut svc_seen = vec![0usize; app.services.len()];
        for zone in &partition.zones {
            for &ni in &zone.nodes {
                node_seen[ni] += 1;
            }
            for &si in &zone.services {
                svc_seen[si] += 1;
            }
        }
        assert!(node_seen.iter().all(|&c| c == 1), "{}", topo.name());
        assert!(svc_seen.iter().all(|&c| c == 1), "{}", topo.name());
    }
}

#[test]
fn sharded_scheduler_works_through_trait_object() {
    let spec = simulate::TopologySpec::new(simulate::Topology::HybridBurst, 40, 80)
        .with_zones(4)
        .with_seed(3);
    let (app, infra) = simulate::topology::generate(&spec);
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &[],
        objective: Objective::default(),
    };
    let solver: Box<dyn Scheduler> = Box::new(ShardedScheduler::default());
    assert_eq!(solver.name(), "sharded-continuum");
    let plan = solver.schedule(&problem).unwrap();
    assert_feasible(&problem, &plan);
}
