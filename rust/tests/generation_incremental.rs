//! Integration property: **full regeneration == incremental
//! regeneration** — identical constraints, τ and ranking — across random
//! perturbation sequences (profile drift, regional carbon swings,
//! compatibility-mask flips, link re-pricing, node failures) on all four
//! continuum topology presets.

use greengen::constraints::{
    Constraint, ConstraintGenerator, ConstraintLibrary, GeneratorConfig, IncrementalGenerator,
};
use greengen::model::{Application, Infrastructure};
use greengen::ranker::Ranker;
use greengen::runtime::NativeBackend;
use greengen::simulate::{topology, Topology, TopologySpec};
use greengen::util::proptest::check;
use greengen::util::Rng;

fn sorted(cs: &[Constraint]) -> Vec<Constraint> {
    let mut out = cs.to_vec();
    out.sort_by(|a, b| a.kind.key().cmp(&b.kind.key()));
    out
}

/// One random epoch-to-epoch change of the kind the adaptive loop sees.
fn perturb(rng: &mut Rng, app: &mut Application, infra: &mut Infrastructure) {
    match rng.below(8) {
        0 => {} // quiet epoch: nothing changed
        1 | 2 | 3 => {
            // a handful of energy profiles drift (the common case)
            for _ in 0..=rng.below(3) {
                let si = rng.below(app.services.len());
                let svc = &mut app.services[si];
                let fi = rng.below(svc.flavours.len());
                if let Some(profile) = &mut svc.flavours[fi].energy {
                    profile.kwh *= rng.range(0.7, 1.4);
                    profile.samples += 1;
                }
            }
        }
        4 => {
            // one region's grid swings (browns out or greens up)
            let region = infra.nodes[rng.below(infra.nodes.len())].region.clone();
            let factor = rng.range(0.5, 1.8);
            for n in &mut infra.nodes {
                if n.region == region {
                    n.profile.carbon = Some((n.carbon() * factor).clamp(10.0, 650.0));
                }
            }
        }
        5 => {
            // a security requirement flips: compatibility masks change
            let si = rng.below(app.services.len());
            let sec = &mut app.services[si].requirements.security;
            sec.firewall = !sec.firewall;
        }
        6 => {
            // a link's learned communication energy moves
            if !app.links.is_empty() {
                let li = rng.below(app.links.len());
                let link = &mut app.links[li];
                if !link.energy.is_empty() {
                    let ei = rng.below(link.energy.len());
                    link.energy[ei].1 *= rng.range(0.5, 2.5);
                }
            }
        }
        _ => {
            // a node fails (structural: the incremental path must detect
            // it and fall back to a full rebuild)
            if infra.nodes.len() > 4 {
                let ni = rng.below(infra.nodes.len());
                infra.nodes.remove(ni);
            }
        }
    }
}

fn drive(topo: Topology, config: GeneratorConfig, nodes: usize, services: usize, seed: u64, epochs: usize) {
    let spec = TopologySpec::new(topo, nodes, services)
        .with_zones(4)
        .with_seed(seed);
    let (mut app, mut infra) = topology::generate(&spec);
    // a third of the fleet offers a firewall, so security flips actually
    // move compatibility masks rather than emptying them
    for (i, n) in infra.nodes.iter_mut().enumerate() {
        if i % 3 == 0 {
            n.capabilities.firewall = true;
        }
    }
    let backend = NativeBackend;
    let library = ConstraintLibrary::default();
    let mut inc = IncrementalGenerator::new(config);
    let mut rng = Rng::new(seed ^ 0xD17);
    let ranker = Ranker::default();

    for epoch in 0..epochs {
        let nodes_before = infra.nodes.len();
        if epoch > 0 {
            perturb(&mut rng, &mut app, &mut infra);
        }
        let node_removed = infra.nodes.len() != nodes_before;
        let full = ConstraintGenerator::new(&backend)
            .with_config(config)
            .generate(&app, &infra)
            .unwrap();
        let (result, stats) = inc.generate(&backend, &library, &app, &infra).unwrap();

        let tag = format!("{} epoch {epoch} (seed {seed:#x})", topo.name());
        // τ and the ranker normaliser: bit-identical (eps 0 <= 1e-9)
        assert_eq!(full.tau.to_bits(), result.tau.to_bits(), "tau diverged: {tag}");
        assert_eq!(full.gmax.to_bits(), result.gmax.to_bits(), "gmax diverged: {tag}");
        // constraint sets: identical down to em / savings bounds
        assert_eq!(
            sorted(&full.constraints),
            sorted(&result.constraints),
            "constraints diverged: {tag}"
        );
        // ranking: identical order and weights
        assert_eq!(
            ranker.rank_fresh(&full.constraints),
            ranker.rank_fresh(&result.constraints),
            "ranking diverged: {tag}"
        );
        // stats sanity: the perturbation menu only changes the node set
        // structurally, so full rebuilds happen exactly on cold start and
        // node failure
        assert_eq!(stats.total_rows, full.rows.len(), "{tag}");
        assert!(stats.dirty_rows <= stats.total_rows, "{tag}");
        assert_eq!(stats.full_rebuild, epoch == 0 || node_removed, "{tag}");
    }
}

const EPOCHS: usize = 7;

#[test]
fn geo_regions_full_equals_incremental() {
    check("geo-regions full == incremental", 4, |rng| {
        let config = GeneratorConfig {
            alpha: 0.8,
            use_prolog: false,
        };
        drive(Topology::GeoRegions, config, 16, 28, rng.next_u64(), EPOCHS);
    });
}

#[test]
fn cloud_edge_hierarchy_full_equals_incremental() {
    check("cloud-edge-hierarchy full == incremental", 4, |rng| {
        let config = GeneratorConfig {
            alpha: 0.8,
            use_prolog: false,
        };
        drive(Topology::CloudEdgeHierarchy, config, 20, 24, rng.next_u64(), EPOCHS);
    });
}

#[test]
fn iot_swarm_full_equals_incremental() {
    check("iot-swarm full == incremental", 4, |rng| {
        let config = GeneratorConfig {
            alpha: 0.8,
            use_prolog: false,
        };
        drive(Topology::IotSwarm, config, 20, 24, rng.next_u64(), EPOCHS);
    });
}

#[test]
fn hybrid_burst_full_equals_incremental() {
    check("hybrid-burst full == incremental", 4, |rng| {
        let config = GeneratorConfig {
            alpha: 0.8,
            use_prolog: false,
        };
        drive(Topology::HybridBurst, config, 16, 28, rng.next_u64(), EPOCHS);
    });
}

#[test]
fn prolog_path_full_equals_incremental() {
    // the paper-formulation Prolog path goes through the same incremental
    // machinery (sub-database over dirty rows); keep the instance small —
    // the rule engine is the expensive part
    check("prolog full == incremental", 2, |rng| {
        drive(
            Topology::GeoRegions,
            GeneratorConfig::default(), // use_prolog: true
            8,
            12,
            rng.next_u64(),
            5,
        );
    });
}

#[test]
fn tighter_alpha_also_agrees() {
    check("alpha 0.5 full == incremental", 2, |rng| {
        let config = GeneratorConfig {
            alpha: 0.5,
            use_prolog: false,
        };
        drive(Topology::GeoRegions, config, 16, 24, rng.next_u64(), 5);
    });
}
