//! Determinism contract of the parallel batch-scoring engine
//! (`scheduler::parscore`) at the public API: candidate sweeps, whole
//! solver runs and the seed-racing portfolio must be **bit-identical**
//! across scoring-thread counts 1/2/4/8 on every topology preset —
//! parallelism is a throughput knob, never a behaviour knob. The CLI
//! golden at the bottom pins the same identity end to end through
//! `greengen schedule --threads N`.

use greengen::constraints::{Constraint, ConstraintGenerator, GeneratorConfig};
use greengen::model::{Application, Infrastructure};
use greengen::runtime::NativeBackend;
use greengen::scheduler::{
    GreedyScheduler, LnsScheduler, Objective, PortfolioScheduler, Problem, Scheduler, ScoreDelta,
    ScoreState,
};
use greengen::simulate;
use std::process::Command;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Topology fleet with generated-and-weighted constraints. 160 nodes
/// puts multi-flavour services past the 256-candidate threshold where
/// `best_reassign` actually fans out, while single-flavour services stay
/// on the sequential fallback — both paths are exercised in one sweep.
fn fleet(
    topo: simulate::Topology,
    seed: u64,
) -> (Application, Infrastructure, Vec<Constraint>) {
    let spec = simulate::TopologySpec::new(topo, 160, 64)
        .with_zones(4)
        .with_seed(seed);
    let (app, infra) = simulate::topology::generate(&spec);
    let backend = NativeBackend;
    let mut constraints = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.7,
            use_prolog: false,
        })
        .generate(&app, &infra)
        .unwrap()
        .constraints;
    for (i, c) in constraints.iter_mut().enumerate() {
        c.weight = 0.1 + 0.05 * (i % 10) as f64;
    }
    (app, infra, constraints)
}

fn objective_bits(problem: &Problem, plan: &greengen::model::DeploymentPlan) -> u64 {
    problem
        .objective_value(&problem.to_assignment(plan).unwrap())
        .to_bits()
}

/// Property: one `best_reassign` sweep per service, repeated at every
/// thread count, returns the identical `(flavour, node, ScoreDelta)`
/// triples — on all four topology presets.
#[test]
fn best_reassign_is_thread_count_invariant_on_every_preset() {
    for topo in simulate::Topology::ALL {
        let (app, infra, constraints) = fleet(topo, 0x9A7_5C0);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        // a capacity-feasible starting assignment via the greedy solver
        let plan = GreedyScheduler {
            max_rounds: 3,
            threads: 1,
        }
        .schedule(&problem)
        .unwrap();
        let assignment = problem.to_assignment(&plan).unwrap();
        let compiled = problem.compile();
        let mut state = ScoreState::new(&compiled, assignment);

        let mut baseline: Option<Vec<Option<(usize, usize, ScoreDelta)>>> = None;
        for threads in THREAD_COUNTS {
            state.set_threads(threads);
            let picks: Vec<Option<(usize, usize, ScoreDelta)>> = (0..app.services.len())
                .map(|si| state.best_reassign(si))
                .collect();
            match &baseline {
                None => baseline = Some(picks),
                Some(b) => assert_eq!(
                    *b, picks,
                    "{}: sweep winners changed at {threads} threads",
                    topo.name()
                ),
            }
        }
    }
}

/// Property: whole solver runs (greedy construction + local search, and
/// the LNS destroy-and-rebuild ladder) produce the identical plan and
/// the identical objective bits at every thread count.
#[test]
fn solver_plans_are_thread_count_invariant() {
    for topo in [
        simulate::Topology::GeoRegions,
        simulate::Topology::CloudEdgeHierarchy,
    ] {
        let (app, infra, constraints) = fleet(topo, 0xBA7C4);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        let reference = GreedyScheduler {
            max_rounds: 5,
            threads: 1,
        }
        .schedule(&problem)
        .unwrap();
        let bits = objective_bits(&problem, &reference);
        for threads in THREAD_COUNTS {
            let plan = GreedyScheduler {
                max_rounds: 5,
                threads,
            }
            .schedule(&problem)
            .unwrap();
            assert_eq!(
                reference,
                plan,
                "{}: greedy plan changed at {threads} threads",
                topo.name()
            );
            assert_eq!(bits, objective_bits(&problem, &plan));
        }
    }

    // the LNS rebuild routes every candidate through the same engine
    let (app, infra, constraints) = fleet(simulate::Topology::IotSwarm, 0x175);
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &constraints,
        objective: Objective::default(),
    };
    let lns = |threads: usize| LnsScheduler {
        rounds: 4,
        greedy_rounds: 5,
        threads,
        ..LnsScheduler::seeded(11)
    };
    let reference = lns(1).schedule(&problem).unwrap();
    let bits = objective_bits(&problem, &reference);
    for threads in [2, 8] {
        let plan = lns(threads).schedule(&problem).unwrap();
        assert_eq!(reference, plan, "LNS plan changed at {threads} threads");
        assert_eq!(bits, objective_bits(&problem, &plan));
    }
}

/// Property: the seed-racing portfolio picks the identical winner —
/// same plan, same objective to 0 ulps — whether the racers run
/// sequentially (threads = 1) or on scoped threads (2/4/8).
#[test]
fn portfolio_race_is_thread_count_invariant() {
    let (app, infra, constraints) = fleet(simulate::Topology::HybridBurst, 0xFACE);
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &constraints,
        objective: Objective::default(),
    };
    let race = |threads: usize| PortfolioScheduler {
        racers: 4,
        threads,
        anneal_iterations: 4_000,
        lns_rounds: 6,
        greedy_rounds: 5,
        ..PortfolioScheduler::seeded(21)
    };
    let reference = race(1).schedule(&problem).unwrap();
    let bits = objective_bits(&problem, &reference);
    for threads in [2, 4, 8] {
        let plan = race(threads).schedule(&problem).unwrap();
        assert_eq!(
            reference, plan,
            "portfolio winner changed at {threads} threads"
        );
        assert_eq!(bits, objective_bits(&problem, &plan));
    }
}

/// End-to-end golden: `greengen schedule --threads N` is byte-identical
/// to `--threads 1` for the solvers with batch-scoring loops.
#[test]
fn schedule_cli_is_byte_identical_across_thread_counts() {
    let run = |solver: &str, threads: &str| -> String {
        let exe = env!("CARGO_BIN_EXE_greengen");
        let out = Command::new(exe)
            .args([
                "schedule", "--scenario", "1", "--solver", solver, "--seed", "5", "--threads",
                threads,
            ])
            .output()
            .expect("binary runs");
        assert!(out.status.success(), "{solver} @ {threads} threads failed");
        String::from_utf8(out.stdout).unwrap()
    };
    for solver in ["portfolio", "lns"] {
        let sequential = run(solver, "1");
        assert!(sequential.contains("deploy"), "{sequential}");
        assert_eq!(
            sequential,
            run(solver, "4"),
            "{solver}: --threads 4 changed the CLI output"
        );
    }
}
