//! Compiled-vs-legacy identity tests for the interned-ID problem core.
//!
//! The refactor's contract: compiling a problem (interned ids, dense
//! per-(service, flavour, node) tensors, CSR link adjacency, resolved
//! constraint rows) changes *nothing* about what is scored — only how
//! fast. This file pins that with an **independent naive reference**: a
//! from-scratch reimplementation of the pre-refactor string-driven
//! scoring (name scans, `String` equality, full link walks), compared
//! against the compiled core across random assignments on all four
//! topology presets, per-move deltas, every registered solver, and the
//! `greengen schedule` CLI output.

use greengen::constraints::{Constraint, ConstraintGenerator, ConstraintKind, GeneratorConfig};
use greengen::model::{Application, DeploymentPlan, Infrastructure};
use greengen::runtime::NativeBackend;
use greengen::scheduler::{
    check_feasible, solver_by_name, Move, Objective, Problem, ScoreState, SOLVER_NAMES,
};
use greengen::simulate::{self, topology, Topology, TopologySpec};
use greengen::util::Rng;

// ---------------------------------------------------------------------
// Naive reference: the pre-refactor string-driven scoring semantics,
// reimplemented without any interner/tensor machinery.
//
// Scope: every constraint these tests use resolves against the current
// model (they come from the generator), which is where the old string
// scan and the old solver-side `ConstraintIndex` agreed. For
// *unresolvable* constraints the two disagreed (stale `PreferNode`),
// and the refactor deliberately unified on the solver semantics —
// pinned by `stale_prefer_node_is_inert_by_design` in
// `constraints::compiled`, not here.
// ---------------------------------------------------------------------

/// `Problem::find` as it was: scan services by name, return the slot.
fn naive_find(
    app: &Application,
    assignment: &[Option<(usize, usize)>],
    service: &str,
) -> Option<(usize, (usize, usize))> {
    let idx = app.services.iter().position(|s| s.id == service)?;
    assignment[idx].map(|a| (idx, a))
}

/// The old `Problem::soft_penalty`: per constraint, a name scan plus
/// `String` equality on the flavour/node.
fn naive_soft_penalty(
    app: &Application,
    infra: &Infrastructure,
    constraints: &[Constraint],
    assignment: &[Option<(usize, usize)>],
) -> f64 {
    let mut penalty = 0.0;
    for c in constraints {
        match &c.kind {
            ConstraintKind::AvoidNode {
                service,
                flavour,
                node,
            } => {
                if let Some((si, (fi, ni))) = naive_find(app, assignment, service) {
                    if app.services[si].flavours[fi].name == *flavour
                        && infra.nodes[ni].id == *node
                    {
                        penalty += c.weight;
                    }
                }
            }
            ConstraintKind::Affinity {
                service,
                flavour,
                other,
            } => {
                if let (Some((si, (fi, ni))), Some((_, (_, nz)))) = (
                    naive_find(app, assignment, service),
                    naive_find(app, assignment, other),
                ) {
                    if app.services[si].flavours[fi].name == *flavour && ni != nz {
                        penalty += c.weight;
                    }
                }
            }
            ConstraintKind::PreferNode {
                service,
                flavour,
                node,
            } => {
                if let Some((si, (fi, ni))) = naive_find(app, assignment, service) {
                    if app.services[si].flavours[fi].name == *flavour
                        && infra.nodes[ni].id != *node
                    {
                        penalty += c.weight;
                    }
                }
            }
        }
    }
    penalty
}

/// The old `Problem::emissions`: compute per service, then a full link
/// walk with a per-link flavour-name scan of the energy pairs.
fn naive_emissions(
    app: &Application,
    infra: &Infrastructure,
    assignment: &[Option<(usize, usize)>],
) -> f64 {
    let mut total = 0.0;
    for (si, slot) in assignment.iter().enumerate() {
        if let Some((fi, ni)) = slot {
            if let Some(profile) = app.services[si].flavours[*fi].energy {
                total += profile.kwh * infra.nodes[*ni].carbon();
            }
        }
    }
    for link in &app.links {
        let from = naive_find(app, assignment, &link.from);
        let to = naive_find(app, assignment, &link.to);
        if let (Some((si, (fi, ni))), Some((_, (_, nz)))) = (from, to) {
            if ni != nz {
                let flavour = &app.services[si].flavours[fi].name;
                let kwh = link
                    .energy
                    .iter()
                    .find(|(f, _)| f == flavour)
                    .map(|(_, e)| *e);
                if let Some(kwh) = kwh {
                    let ci = 0.5 * (infra.nodes[ni].carbon() + infra.nodes[nz].carbon());
                    total += kwh * ci;
                }
            }
        }
    }
    total
}

/// The old `Problem::objective_value` on top of the naive terms.
fn naive_objective(problem: &Problem, assignment: &[Option<(usize, usize)>]) -> f64 {
    let o = &problem.objective;
    let mut cost = 0.0;
    let mut flavour_rank = 0.0;
    let mut dropped = 0.0;
    for (si, slot) in assignment.iter().enumerate() {
        match slot {
            Some((fi, ni)) => {
                let svc = &problem.app.services[si];
                let req = &svc.flavours[*fi].requirements;
                cost += req.cpu * problem.infra.nodes[*ni].profile.cost_per_cpu_hour;
                flavour_rank += *fi as f64;
            }
            None => dropped += 1.0,
        }
    }
    let mut value = o.cost_weight * cost
        + o.soft_weight * naive_soft_penalty(problem.app, problem.infra, problem.constraints, assignment)
        + o.drop_penalty * dropped
        + o.flavour_weight * flavour_rank;
    if o.emissions_weight != 0.0 {
        value += o.emissions_weight * naive_emissions(problem.app, problem.infra, assignment);
    }
    value
}

// ---------------------------------------------------------------------
// Fixtures
// ---------------------------------------------------------------------

const PRESETS: [Topology; 4] = [
    Topology::CloudEdgeHierarchy,
    Topology::GeoRegions,
    Topology::IotSwarm,
    Topology::HybridBurst,
];

fn fleet(topo: Topology, seed: u64) -> (Application, Infrastructure, Vec<Constraint>) {
    let spec = TopologySpec::new(topo, 20, 40).with_zones(4).with_seed(seed);
    let (app, infra) = topology::generate(&spec);
    let backend = NativeBackend;
    let mut constraints = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.7,
            use_prolog: false,
        })
        .generate(&app, &infra)
        .unwrap()
        .constraints;
    for (i, c) in constraints.iter_mut().enumerate() {
        c.weight = 0.1 + 0.05 * (i % 10) as f64;
    }
    (app, infra, constraints)
}

fn random_assignment(rng: &mut Rng, app: &Application, nodes: usize) -> Vec<Option<(usize, usize)>> {
    app.services
        .iter()
        .map(|s| {
            if rng.chance(0.85) {
                Some((rng.below(s.flavours.len()), rng.below(nodes)))
            } else {
                None
            }
        })
        .collect()
}

// ---------------------------------------------------------------------
// Properties
// ---------------------------------------------------------------------

/// Compiled scoring equals the naive string reference to 1e-12 on every
/// topology preset, for both objective configurations.
#[test]
fn property_compiled_equals_naive_on_all_presets() {
    for (p, topo) in PRESETS.into_iter().enumerate() {
        let (app, infra, constraints) = fleet(topo, 0xC0FE + p as u64);
        for emissions_weight in [0.0, 1.0] {
            let problem = Problem {
                app: &app,
                infra: &infra,
                constraints: &constraints,
                objective: Objective {
                    emissions_weight,
                    ..Objective::default()
                },
            };
            let compiled = problem.compile();
            let mut rng = Rng::new(0xF00D + p as u64);
            for _ in 0..16 {
                let a = random_assignment(&mut rng, &app, infra.nodes.len());
                let naive_pen =
                    naive_soft_penalty(&app, &infra, &constraints, &a);
                let naive_em = naive_emissions(&app, &infra, &a);
                let naive_obj = naive_objective(&problem, &a);
                assert!(
                    (compiled.soft_penalty(&a) - naive_pen).abs() <= 1e-12,
                    "{topo:?}: penalty {} vs naive {naive_pen}",
                    compiled.soft_penalty(&a)
                );
                assert!(
                    (compiled.emissions(&a) - naive_em).abs() <= 1e-12,
                    "{topo:?}: emissions {} vs naive {naive_em}",
                    compiled.emissions(&a)
                );
                assert!(
                    (compiled.objective_value(&a) - naive_obj).abs() <= 1e-12,
                    "{topo:?}: objective {} vs naive {naive_obj} (ew {emissions_weight})",
                    compiled.objective_value(&a)
                );
                // the legacy wrappers stay on the same arithmetic
                assert_eq!(problem.soft_penalty(&a), compiled.soft_penalty(&a));
                assert_eq!(problem.objective_value(&a), compiled.objective_value(&a));
                assert_eq!(problem.emissions(&a), compiled.emissions(&a));
            }
        }
    }
}

/// Per-move deltas agree with the naive full-rescore difference, and
/// the delta-tracked state keeps matching the naive reference after
/// every move (1e-9 — the delta-vs-full comparison is limited by f64
/// cancellation of two large sums; the per-assignment values themselves
/// agree to 1e-12 above).
#[test]
fn property_per_move_deltas_match_naive_rescore() {
    for (p, topo) in PRESETS.into_iter().enumerate() {
        let (app, infra, constraints) = fleet(topo, 0xDE17 + p as u64);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective {
                emissions_weight: 1.0,
                ..Objective::default()
            },
        };
        let compiled = problem.compile();
        let mut state = ScoreState::new(&compiled, vec![None; app.services.len()]);
        let mut rng = Rng::new(0xBEA7 + p as u64);
        let mut applied = 0;
        for _ in 0..150 {
            let before = naive_objective(&problem, state.assignment());
            let si = rng.below(app.services.len());
            let mv = match rng.below(4) {
                0 => Move::Drop { service: si },
                1 => Move::Swap {
                    a: si,
                    b: rng.below(app.services.len()),
                },
                _ => Move::Reassign {
                    service: si,
                    flavour: rng.below(app.services[si].flavours.len()),
                    node: rng.below(infra.nodes.len()),
                },
            };
            if let Some(d) = state.apply(mv) {
                applied += 1;
                let after = naive_objective(&problem, state.assignment());
                assert!(
                    ((after - before) - d.total).abs() < 1e-9,
                    "{topo:?}: delta {} vs naive diff {}",
                    d.total,
                    after - before
                );
                assert!(
                    (state.objective() - after).abs() < 1e-9,
                    "{topo:?}: tracked {} vs naive {after}",
                    state.objective()
                );
            }
        }
        assert!(applied > 30, "{topo:?}: too few feasible moves ({applied})");
    }
}

/// Every registered solver produces, deterministically, a plan whose
/// compiled score equals the naive reference score (and stays feasible).
#[test]
fn all_registered_solvers_agree_with_naive_scoring() {
    let mut rng = Rng::new(0x50_17E5);
    let app = simulate::random_application(&mut rng, 6);
    let infra = simulate::random_infrastructure(&mut rng, 4);
    let backend = NativeBackend;
    let mut constraints = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.6,
            use_prolog: false,
        })
        .generate(&app, &infra)
        .unwrap()
        .constraints;
    for (i, c) in constraints.iter_mut().enumerate() {
        c.weight = 0.1 + 0.05 * (i % 10) as f64;
    }
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &constraints,
        objective: Objective::default(),
    };
    let compiled = problem.compile();
    for name in SOLVER_NAMES {
        let solver = solver_by_name(name, 7).unwrap();
        let Ok(plan) = solver.schedule(&problem) else {
            continue; // consistently infeasible is fine for baselines
        };
        check_feasible(&problem, &plan)
            .unwrap_or_else(|e| panic!("{name}: infeasible plan: {e}"));
        let assignment = problem.to_assignment(&plan).unwrap();
        let compiled_v = compiled.objective_value(&assignment);
        let naive_v = naive_objective(&problem, &assignment);
        assert!(
            (compiled_v - naive_v).abs() <= 1e-12,
            "{name}: compiled {compiled_v} vs naive {naive_v}"
        );
        // same candidate order ⇒ byte-identical plans across runs
        let again = solver_by_name(name, 7).unwrap().schedule(&problem).unwrap();
        assert_eq!(plan, again, "{name}: non-deterministic plan");
    }
}

// ---------------------------------------------------------------------
// Golden: `greengen schedule` output
// ---------------------------------------------------------------------

/// The `greengen schedule` stdout is byte-identical across invocations
/// and byte-identical to an in-process reconstruction of the pipeline +
/// greedy solve + evaluation (which the compiled-vs-naive properties
/// above pin to the pre-refactor scoring). Together these freeze the
/// CLI contract across the interned-ID refactor.
#[test]
fn schedule_cli_output_is_golden() {
    let exe = env!("CARGO_BIN_EXE_greengen");
    let run = || {
        let out = std::process::Command::new(exe)
            .args(["schedule", "--scenario", "1"])
            .output()
            .expect("binary runs");
        assert!(out.status.success());
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    let first = run();
    let second = run();
    assert_eq!(first, second, "schedule output not deterministic");

    // in-process reconstruction of cmd_schedule's flow (scenario 1,
    // defaults: alpha 0.8, prolog path, native backend, greedy, seed 7)
    let scenario = greengen::config::scenarios::scenario(1).unwrap();
    let mut config = greengen::pipeline::PipelineConfig::default();
    config.generator.alpha = 0.8;
    let mut pipe = greengen::pipeline::GeneratorPipeline::new(config);
    let outcome = pipe.run_scenario(&scenario).unwrap();

    let mut app = scenario.app.clone();
    let mut infra = scenario.infra.clone();
    let mut sim =
        greengen::monitoring::WorkloadSimulator::new(scenario.truth.clone(), scenario.seed);
    let store = sim.run(0.0, scenario.windows);
    let estimator = greengen::energy::EnergyEstimator::default();
    estimator.estimate(&mut app, &store);
    let gatherer = greengen::carbon::EnergyMixGatherer::new(&scenario.intensity);
    gatherer.enrich(&mut infra, store.horizon()).unwrap();

    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &outcome.ranked,
        objective: Objective::default(),
    };
    let plan: DeploymentPlan = solver_by_name("greedy", 7)
        .unwrap()
        .schedule(&problem)
        .unwrap();
    let metrics = greengen::scheduler::evaluate(&problem, &plan).unwrap();

    let mut expected = format!("# solver=greedy constraints={}\n", outcome.ranked.len());
    for p in &plan.placements {
        expected.push_str(&format!("deploy {} ({}) -> {}\n", p.service, p.flavour, p.node));
    }
    for d in &plan.dropped {
        expected.push_str(&format!("drop   {d}\n"));
    }
    expected.push_str(&format!(
        "\nemissions={:.1} gCO2eq/window  cost={:.3}/h  violations={} (weight {:.2})  dropped={}\n",
        metrics.emissions_g,
        metrics.cost,
        metrics.violations,
        metrics.violation_weight,
        metrics.dropped
    ));
    assert_eq!(first, expected, "schedule output diverged from the library");
}
