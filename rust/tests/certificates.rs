//! Optimality-certificate test suite (the PR's pinned acceptance bar):
//!
//! * **Admissibility** — every registry solver returns a
//!   [`Certificate`] with `lower_bound <= objective` on every topology
//!   preset, across seeds.
//! * **Exactness** — branch-and-bound proves `gap == 0` on small
//!   instances, and its certified optimum lower-bounds every other
//!   solver's objective.
//! * **Incrementality** — the re-planner's continuum bound is bitwise
//!   stable across clean epochs and zone invalidations.
//! * **Cross-verification** — the declarative (Prolog) checker and the
//!   compiled evaluator agree on randomized plans, including infeasible
//!   and deliberately corrupted ones.

use greengen::constraints::{cross_check, Constraint, ConstraintGenerator, GeneratorConfig};
use greengen::continuum::{IncrementalReplanner, ShardedScheduler};
use greengen::model::{Application, DeploymentPlan, Infrastructure, Placement};
use greengen::runtime::NativeBackend;
use greengen::scheduler::{
    check_feasible, solver_by_name, BranchAndBoundScheduler, Objective, Problem, Scheduler,
    SOLVER_NAMES,
};
use greengen::simulate::{self, topology, Topology, TopologySpec};
use greengen::util::proptest::check;
use greengen::util::Rng;

/// Random instance with generated-and-weighted green constraints.
fn instance(
    rng: &mut Rng,
    services: usize,
    nodes: usize,
    capacity_scale: f64,
) -> (Application, Infrastructure, Vec<Constraint>) {
    let app = simulate::random_application(rng, services);
    let mut infra = simulate::random_infrastructure(rng, nodes);
    for n in &mut infra.nodes {
        n.capabilities.cpu *= capacity_scale;
        n.capabilities.ram_gb *= capacity_scale;
    }
    let backend = NativeBackend;
    let mut constraints = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.7,
            use_prolog: false,
        })
        .generate(&app, &infra)
        .unwrap()
        .constraints;
    for (i, c) in constraints.iter_mut().enumerate() {
        c.weight = 0.1 + 0.05 * (i % 10) as f64;
    }
    (app, infra, constraints)
}

#[test]
fn every_solver_certifies_every_topology_preset() {
    for t in Topology::ALL {
        for seed in [1u64, 42, 0xC0FFEE] {
            let spec = TopologySpec::new(t, 6, 10).with_zones(2).with_seed(seed);
            let (app, mut infra) = topology::generate(&spec);
            // 2x capacity headroom: the property under test is the
            // certificate algebra, not knife-edge feasibility
            for n in &mut infra.nodes {
                n.capabilities.cpu *= 2.0;
                n.capabilities.ram_gb *= 2.0;
            }
            let backend = NativeBackend;
            let constraints = ConstraintGenerator::new(&backend)
                .with_config(GeneratorConfig {
                    alpha: 0.7,
                    use_prolog: false,
                })
                .generate(&app, &infra)
                .unwrap()
                .constraints;
            let problem = Problem {
                app: &app,
                infra: &infra,
                constraints: &constraints,
                objective: Objective::default(),
            };
            for name in SOLVER_NAMES {
                let solver = solver_by_name(name, seed).unwrap();
                let (plan, cert) = solver
                    .certified_schedule(&problem)
                    .unwrap_or_else(|e| panic!("{name} on {} seed {seed}: {e}", t.name()));
                check_feasible(&problem, &plan).unwrap();
                assert!(
                    cert.lower_bound.is_finite(),
                    "{name} on {} seed {seed}: bound {}",
                    t.name(),
                    cert.lower_bound
                );
                assert!(
                    cert.gap >= -1e-9,
                    "{name} on {} seed {seed}: objective {} below bound {}",
                    t.name(),
                    cert.objective,
                    cert.lower_bound
                );
                let expect = cert.objective - cert.lower_bound;
                assert!((cert.gap - expect).abs() <= 1e-12, "gap algebra broke");
            }
        }
    }
}

#[test]
fn property_bnb_certifies_gap_zero_and_lower_bounds_every_solver() {
    check("bnb gap==0 bounds the registry", 24, |rng| {
        let services = 3 + rng.below(3); // 3..=5
        let nodes = 2 + rng.below(3); // 2..=4
        let (app, infra, constraints) = instance(rng, services, nodes, 2.0);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        let Ok((_, exact)) = BranchAndBoundScheduler::default().certified_schedule(&problem)
        else {
            return; // knife-edge instance: nothing to certify
        };
        // small instance, 2M-node cap: the search always completes, so
        // the certificate is exact
        assert_eq!(exact.gap, 0.0, "bnb truncated on a tiny instance");
        assert_eq!(exact.objective.to_bits(), exact.lower_bound.to_bits());
        for name in SOLVER_NAMES {
            let solver = solver_by_name(name, 0xBEE5).unwrap();
            let Ok((_, cert)) = solver.certified_schedule(&problem) else {
                continue; // heuristic failed a feasible-but-tight instance
            };
            assert!(cert.gap >= -1e-9, "{name}: inadmissible certificate");
            // the proven optimum lower-bounds every solver's objective
            assert!(
                cert.objective >= exact.objective - 1e-6,
                "{name} objective {} beat the proven optimum {}",
                cert.objective,
                exact.objective
            );
            // and every solver's relaxation bound admits the optimum
            assert!(
                cert.lower_bound <= exact.objective + 1e-6,
                "{name} bound {} above the optimum {}",
                cert.lower_bound,
                exact.objective
            );
        }
    });
}

#[test]
fn replanner_bound_is_bitwise_stable_across_clean_epochs_and_invalidation() {
    let spec = TopologySpec::new(Topology::GeoRegions, 24, 48)
        .with_zones(4)
        .with_seed(0xFACADE);
    let (app, infra) = topology::generate(&spec);
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &[],
        objective: Objective::default(),
    };
    let mut rp = IncrementalReplanner::new(ShardedScheduler::default());
    let first = rp.replan(&problem).unwrap();
    assert!(first.certificate.gap >= -1e-9);
    assert!(first.certificate.lower_bound.is_finite());
    // clean epoch: every zone bound is a cache hit, the continuum bound
    // is byte-identical
    let second = rp.replan(&problem).unwrap();
    assert!(second.dirty_zones.is_empty());
    assert_eq!(
        first.certificate.lower_bound.to_bits(),
        second.certificate.lower_bound.to_bits()
    );
    // invalidation re-solves the zone's plan, but the model is
    // unchanged, so the bound neither rises nor falls by a single bit
    rp.invalidate_zones(&["z01".to_string()]);
    let third = rp.replan(&problem).unwrap();
    assert_eq!(third.dirty_zones, vec!["z01".to_string()]);
    assert_eq!(
        first.certificate.lower_bound.to_bits(),
        third.certificate.lower_bound.to_bits()
    );
    assert!(third.certificate.gap >= -1e-9);
}

/// Random (not necessarily feasible) plan over valid names: services
/// drop with probability ~0.25, otherwise land on a random flavour and
/// node with no capacity discipline.
fn random_plan(rng: &mut Rng, app: &Application, infra: &Infrastructure) -> DeploymentPlan {
    let mut plan = DeploymentPlan::default();
    for s in &app.services {
        if rng.chance(0.25) {
            plan.dropped.push(s.id.clone());
            continue;
        }
        let f = &s.flavours[rng.below(s.flavours.len())];
        let n = &infra.nodes[rng.below(infra.nodes.len())];
        plan.placements.push(Placement {
            service: s.id.clone(),
            flavour: f.name.clone(),
            node: n.id.clone(),
        });
    }
    plan
}

#[test]
fn property_declarative_checker_agrees_with_compiled_on_random_plans() {
    check("declarative vs compiled differential", 48, |rng| {
        let services = 4 + rng.below(5); // 4..=8
        let nodes = 2 + rng.below(4); // 2..=5
        let (app, infra, constraints) = instance(rng, services, nodes, 1.0);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        let plan = random_plan(rng, &app, &infra);
        let report = cross_check(&problem, &plan).unwrap();
        assert!(
            report.feasible_agrees(),
            "feasibility split: rust={:?} missing={:?} over={:?}",
            report.rust_error,
            report.missing_mandatory,
            report.over_capacity
        );
        assert!(
            report.penalty_agrees(),
            "penalty split: compiled={} declarative={}",
            report.compiled_penalty,
            report.declarative_penalty
        );
    });
}

#[test]
fn corrupted_plan_is_flagged_by_both_checkers() {
    let mut rng = Rng::new(0xBAD);
    let (mut app, infra, constraints) = instance(&mut rng, 6, 4, 2.0);
    app.services[0].must_deploy = true;
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &constraints,
        objective: Objective::default(),
    };
    let solver = solver_by_name("greedy", 7).unwrap();
    let (mut plan, _) = solver.certified_schedule(&problem).unwrap();
    let clean = cross_check(&problem, &plan).unwrap();
    assert!(clean.agrees() && clean.clean(), "{}", clean.render_text());

    // corruption: silently drop the mandatory service
    let victim = app.services[0].id.clone();
    plan.placements.retain(|p| p.service != victim);
    plan.dropped.push(victim.clone());
    let report = cross_check(&problem, &plan).unwrap();
    assert!(report.agrees(), "{}", report.render_text());
    assert!(!report.clean());
    assert!(!report.rust_feasible);
    assert!(
        report.missing_mandatory.contains(&victim),
        "declarative checker missed the dropped mandatory service"
    );
}
