//! Integration: the XLA (PJRT, AOT HLO artifacts) and native backends must
//! produce identical analytics outputs (up to f32 rounding), including
//! under padding — the core cross-layer correctness signal on the Rust
//! side, mirroring python/tests.
//!
//! Requires `make artifacts` to have produced `artifacts/manifest.json`.

use greengen::runtime::{AnalyticsBackend, AnalyticsInput, NativeBackend, XlaBackend};
use greengen::util::Rng;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what} length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() / denom < 1e-5,
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

fn compare(input: &AnalyticsInput, xla: &XlaBackend) {
    let native = NativeBackend.run(input).unwrap();
    let accel = xla.run(input).unwrap();
    assert_close(&accel.impact, &native.impact, "impact");
    assert_close(&[accel.tau], &[native.tau], "tau");
    assert_close(&[accel.gmax], &[native.gmax], "gmax");
    assert_close(&accel.row_min, &native.row_min, "row_min");
    assert_close(&accel.row_max, &native.row_max, "row_max");
    assert_close(&accel.row_max2, &native.row_max2, "row_max2");
    assert_close(&accel.sav_hi, &native.sav_hi, "sav_hi");
    assert_close(&accel.sav_lo, &native.sav_lo, "sav_lo");
}

fn random_input(rng: &mut Rng, rows: usize, nodes: usize, density: f64) -> AnalyticsInput {
    let e: Vec<f32> = (0..rows).map(|_| rng.range(0.0, 5.0) as f32).collect();
    let c: Vec<f32> = (0..nodes).map(|_| rng.range(0.0, 700.0) as f32).collect();
    let mask: Vec<f32> = (0..rows * nodes)
        .map(|_| if rng.chance(density) { 1.0 } else { 0.0 })
        .collect();
    let pool: Vec<f32> = (0..rows / 2).map(|_| rng.range(0.0, 200.0) as f32).collect();
    AnalyticsInput {
        e,
        c,
        mask,
        pool,
        alpha: 0.8,
    }
}

#[test]
fn paper_scenario1_shapes() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let xla = XlaBackend::from_default_artifacts().unwrap();
    // Online Boutique: 15 flavour rows x 5 EU nodes (Tables 1-2)
    let e = vec![
        1.981, 1.585, 1.189, 0.134, 0.107, 0.539, 0.431, 0.989, 0.791, 0.251, 0.546, 0.098,
        0.881, 0.034, 0.050,
    ]
    .into_iter()
    .map(|x: f64| x as f32)
    .collect::<Vec<f32>>();
    let c = vec![16.0, 88.0, 132.0, 213.0, 335.0];
    let input = AnalyticsInput {
        mask: vec![1.0; e.len() * c.len()],
        e,
        c,
        pool: vec![0.01, 0.02, 0.004],
        alpha: 0.8,
    };
    compare(&input, &xla);
}

#[test]
fn randomized_instances_across_buckets() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let xla = XlaBackend::from_default_artifacts().unwrap();
    let mut rng = Rng::new(0xE0E0);
    // Shapes straddling several bucket boundaries, incl. exact fits.
    for (rows, nodes) in [
        (1usize, 1usize),
        (3, 7),
        (64, 8),
        (65, 8),
        (64, 9),
        (100, 30),
        (130, 40),
        (512, 128),
    ] {
        for density in [1.0, 0.6, 0.1] {
            let input = random_input(&mut rng, rows, nodes, density);
            compare(&input, &xla);
        }
    }
}

#[test]
fn all_masked_instance() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let xla = XlaBackend::from_default_artifacts().unwrap();
    let input = AnalyticsInput {
        e: vec![1.0; 10],
        c: vec![100.0; 4],
        mask: vec![0.0; 40],
        pool: vec![],
        alpha: 0.8,
    };
    compare(&input, &xla);
}

#[test]
fn oversize_instance_reports_error() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let xla = XlaBackend::from_default_artifacts().unwrap();
    let rows = 5000; // larger than the biggest bucket (4096)
    let input = AnalyticsInput {
        e: vec![1.0; rows],
        c: vec![1.0; 4],
        mask: vec![1.0; rows * 4],
        pool: vec![],
        alpha: 0.8,
    };
    let err = xla.run(&input);
    assert!(err.is_err());
    assert!(err.unwrap_err().to_string().contains("exceeds"));
}
