//! Integration properties for the fast monitoring-to-constraints path:
//!
//! 1. **Thread-count invariance** — `ConstraintGenerator::generate` and
//!    `IncrementalGenerator::generate` produce bit-identical results at
//!    any worker-thread count (1/2/4/8) on all four continuum topology
//!    presets, for both the direct and the Prolog evaluation paths.
//! 2. **Store API equivalence** — the interned id-based `MetricStore`
//!    accessors describe exactly the same data as the legacy String
//!    wrappers, across randomized out-of-order and compacted streams.
//! 3. **Estimator exactness** — the streaming incremental estimator is
//!    exactly equal (f64-exact summaries) to a full re-scan after every
//!    epoch of appends, out-of-order inserts, and compactions.

use greengen::constraints::{
    Constraint, ConstraintGenerator, ConstraintLibrary, GeneratorConfig, IncrementalGenerator,
};
use greengen::energy::estimator::EstimationReport;
use greengen::energy::EnergyEstimator;
use greengen::model::{Application, Infrastructure};
use greengen::monitoring::{EnergySample, MetricStore, TrafficSample};
use greengen::runtime::NativeBackend;
use greengen::simulate::{topology, Topology, TopologySpec};
use greengen::util::proptest::check;
use greengen::util::Rng;

const TOPOLOGIES: [Topology; 4] = [
    Topology::GeoRegions,
    Topology::CloudEdgeHierarchy,
    Topology::IotSwarm,
    Topology::HybridBurst,
];

/// Instances large enough that `run_library` / `run_threads` actually
/// take their parallel paths (both gate on >= 32 items).
fn instance(topo: Topology, seed: u64) -> (Application, Infrastructure) {
    let spec = TopologySpec::new(topo, 12, 48).with_zones(4).with_seed(seed);
    topology::generate(&spec)
}

fn assert_identical(a: &[Constraint], b: &[Constraint], tag: &str) {
    // order-sensitive: parallel chunk merge must reproduce the exact
    // sequential emission order, not just the same set
    assert_eq!(a, b, "constraint stream diverged: {tag}");
}

// ---------------------------------------------------------------------------
// 1a. full generation: threads 2/4/8 == threads 1, all presets
// ---------------------------------------------------------------------------

#[test]
fn generate_is_thread_count_invariant_direct() {
    let backend = NativeBackend;
    let config = GeneratorConfig {
        alpha: 0.8,
        use_prolog: false,
    };
    for (i, &topo) in TOPOLOGIES.iter().enumerate() {
        let (app, infra) = instance(topo, 0x6E47 + i as u64);
        let baseline = ConstraintGenerator::new(&backend)
            .with_config(config)
            .with_library(ConstraintLibrary::extended())
            .generate(&app, &infra)
            .unwrap();
        assert!(
            baseline.rows.len() >= 32,
            "instance too small to exercise the parallel path ({} rows)",
            baseline.rows.len()
        );
        for threads in [2, 4, 8] {
            let par = ConstraintGenerator::new(&backend)
                .with_config(config)
                .with_library(ConstraintLibrary::extended())
                .with_threads(threads)
                .generate(&app, &infra)
                .unwrap();
            let tag = format!("{} direct threads={threads}", topo.name());
            assert_eq!(baseline.tau.to_bits(), par.tau.to_bits(), "tau: {tag}");
            assert_eq!(baseline.gmax.to_bits(), par.gmax.to_bits(), "gmax: {tag}");
            assert_eq!(baseline.rows, par.rows, "rows: {tag}");
            assert_eq!(baseline.nodes, par.nodes, "nodes: {tag}");
            assert_identical(&baseline.constraints, &par.constraints, &tag);
        }
    }
}

#[test]
fn generate_is_thread_count_invariant_prolog() {
    let backend = NativeBackend;
    let config = GeneratorConfig {
        alpha: 0.8,
        use_prolog: true,
    };
    // one preset suffices for the Prolog engine (it is much slower); the
    // chunk-merge argument is path-independent of the topology shape
    let (app, infra) = instance(Topology::GeoRegions, 0x9601);
    let baseline = ConstraintGenerator::new(&backend)
        .with_config(config)
        .generate(&app, &infra)
        .unwrap();
    for threads in [2, 4, 8] {
        let par = ConstraintGenerator::new(&backend)
            .with_config(config)
            .with_threads(threads)
            .generate(&app, &infra)
            .unwrap();
        let tag = format!("prolog threads={threads}");
        assert_eq!(baseline.tau.to_bits(), par.tau.to_bits(), "tau: {tag}");
        assert_identical(&baseline.constraints, &par.constraints, &tag);
    }
}

// ---------------------------------------------------------------------------
// 1b. incremental generation: threaded == sequential, epoch by epoch
// ---------------------------------------------------------------------------

#[test]
fn incremental_is_thread_count_invariant() {
    let backend = NativeBackend;
    let config = GeneratorConfig {
        alpha: 0.8,
        use_prolog: false,
    };
    let library = ConstraintLibrary::extended();
    for (i, &topo) in TOPOLOGIES.iter().enumerate() {
        let (mut app, mut infra) = instance(topo, 0x1A2B + i as u64);
        let mut seq = IncrementalGenerator::new(config);
        let mut par = IncrementalGenerator::new(config).with_threads(4);
        let mut rng = Rng::new(0xF00D + i as u64);
        for epoch in 0..5 {
            match epoch {
                0 => {} // cold start: both run the full (parallel) rebuild
                3 => {
                    // structural change: node failure forces a threaded
                    // full rebuild mid-sequence
                    let ni = rng.below(infra.nodes.len());
                    infra.nodes.remove(ni);
                }
                _ => {
                    // the common case: a few profiles drift -> dirty-row
                    // sub-instance runs through the chunked path
                    for _ in 0..3 {
                        let si = rng.below(app.services.len());
                        let svc = &mut app.services[si];
                        let fi = rng.below(svc.flavours.len());
                        if let Some(profile) = &mut svc.flavours[fi].energy {
                            profile.kwh *= rng.range(0.8, 1.3);
                            profile.samples += 1;
                        }
                    }
                }
            }
            let (rs, ss) = seq.generate(&backend, &library, &app, &infra).unwrap();
            let (rp, sp) = par.generate(&backend, &library, &app, &infra).unwrap();
            let tag = format!("{} epoch {epoch}", topo.name());
            assert_eq!(ss, sp, "stats diverged: {tag}");
            assert_eq!(rs.tau.to_bits(), rp.tau.to_bits(), "tau: {tag}");
            assert_eq!(rs.gmax.to_bits(), rp.gmax.to_bits(), "gmax: {tag}");
            assert_eq!(rs.rows, rp.rows, "rows: {tag}");
            assert_identical(&rs.constraints, &rp.constraints, &tag);
        }
    }
}

// ---------------------------------------------------------------------------
// 2. interned store: id API == String API on randomized streams
// ---------------------------------------------------------------------------

fn sample_key(rng: &mut Rng) -> (String, String) {
    (format!("s{}", rng.below(5)), format!("f{}", rng.below(3)))
}

#[test]
fn store_id_api_matches_string_api() {
    check("store id API == String API", 8, |rng| {
        let mut store = MetricStore::new();
        let mut t = 0.0;
        for _ in 0..200 {
            // mostly appends; sometimes an out-of-order insert (prefix
            // rewrite); sometimes a compaction (prefix drain)
            t += rng.range(0.1, 2.0);
            let at = if rng.chance(0.15) { t * rng.range(0.1, 0.9) } else { t };
            if rng.chance(0.6) {
                let (service, flavour) = sample_key(rng);
                store.push_energy(EnergySample {
                    t: at,
                    service,
                    flavour,
                    joules: rng.range(1.0, 5e5),
                });
            } else {
                let (from, from_flavour) = sample_key(rng);
                store.push_traffic(TrafficSample {
                    t: at,
                    from,
                    from_flavour,
                    to: format!("s{}", rng.below(5)),
                    requests: rng.range(1.0, 100.0),
                    bytes: rng.range(1.0, 1e9),
                });
            }
            if rng.chance(0.03) {
                store.compact(t * rng.range(0.1, 0.5));
            }
        }

        // --- id <-> key round trip -----------------------------------
        for id in store.energy_series_ids().collect::<Vec<_>>() {
            let (service, flavour) = store.energy_series_key(id).unwrap();
            assert_eq!(store.energy_series_id(service, flavour), Some(id));
        }
        for id in store.traffic_series_ids().collect::<Vec<_>>() {
            let (from, flavour, to) = store.traffic_series_key(id).unwrap();
            assert_eq!(store.traffic_series_id(from, flavour, to), Some(id));
        }

        // --- columnar reconstruction == String range query -----------
        let key = |s: &EnergySample| {
            (
                s.t.to_bits(),
                s.service.clone(),
                s.flavour.clone(),
                s.joules.to_bits(),
            )
        };
        let mut via_ids: Vec<EnergySample> = Vec::new();
        for id in store.energy_series_ids().collect::<Vec<_>>() {
            let (service, flavour) = store.energy_series_key(id).unwrap();
            let (service, flavour) = (service.to_string(), flavour.to_string());
            let series = store.energy_series(id).unwrap();
            assert_eq!(series.times().len(), series.joules().len());
            assert_eq!(series.len(), series.times().len());
            for i in 0..series.len() {
                via_ids.push(EnergySample {
                    t: series.times()[i],
                    service: service.clone(),
                    flavour: flavour.clone(),
                    joules: series.joules()[i],
                });
            }
        }
        let mut via_strings = store.energy_range(f64::NEG_INFINITY, f64::INFINITY);
        assert_eq!(via_ids.len(), store.energy_len());
        via_ids.sort_by_key(key);
        via_strings.sort_by_key(key);
        assert_eq!(via_ids, via_strings);

        // --- binary-search windows == linear filtering ----------------
        let (lo, hi) = (rng.range(0.0, t), rng.range(0.0, t));
        let (lo, hi) = (lo.min(hi), lo.max(hi));
        for id in store.energy_series_ids().collect::<Vec<_>>() {
            let series = store.energy_series(id).unwrap();
            let window = series.window(lo, hi);
            let expect: Vec<usize> = (0..series.len())
                .filter(|&i| series.times()[i] > lo && series.times()[i] <= hi)
                .collect();
            assert_eq!(window.collect::<Vec<_>>(), expect);
        }

        // --- touched-series sets agree --------------------------------
        let since = rng.below(store.revision() as usize + 1) as u64;
        let by_id: Vec<(String, String)> = store
            .energy_touched_ids(since)
            .filter_map(|id| store.energy_series_key(id))
            .map(|(s, f)| (s.to_string(), f.to_string()))
            .collect();
        let by_string: Vec<(String, String)> = store
            .energy_touched_since(since)
            .into_iter()
            .map(|(s, f)| (s.to_string(), f.to_string()))
            .collect();
        assert_eq!(by_id, by_string);
    });
}

// ---------------------------------------------------------------------------
// 3. estimator: streaming summaries == full re-scan, exactly
// ---------------------------------------------------------------------------

#[test]
fn estimator_streaming_matches_full_rescan() {
    check("estimator streaming == rescan", 6, |rng| {
        let spec = TopologySpec::new(Topology::GeoRegions, 6, 8).with_seed(rng.next_u64());
        let (app, _infra) = topology::generate(&spec);
        let mut app_full = app.clone();
        let mut app_inc = app.clone();

        let estimator = EnergyEstimator::default();
        let mut store = MetricStore::new();
        let mut t = 0.0;
        let mut since = store.revision();
        let mut prev = EstimationReport::default();

        for epoch in 0..6 {
            for _ in 0..30 {
                t += rng.range(0.1, 1.5);
                let at = if rng.chance(0.2) { t * rng.range(0.1, 0.9) } else { t };
                if rng.chance(0.6) {
                    let (service, flavour) = sample_key(rng);
                    store.push_energy(EnergySample {
                        t: at,
                        service,
                        flavour,
                        joules: rng.range(1.0, 7.2e5),
                    });
                } else {
                    let (from, from_flavour) = sample_key(rng);
                    store.push_traffic(TrafficSample {
                        t: at,
                        from,
                        from_flavour,
                        to: format!("s{}", rng.below(5)),
                        requests: rng.range(1.0, 50.0),
                        bytes: rng.range(1e3, 2e9),
                    });
                }
            }
            if epoch == 3 {
                store.compact(t * 0.4);
            }

            let full = estimator.estimate(&mut app_full, &store);
            let inc = estimator.estimate_incremental(&mut app_inc, &store, &prev, since);
            // Summary is compared with f64-exact equality: the streaming
            // path must replay the identical accumulation, not merely
            // approximate it
            assert_eq!(full.computation, inc.computation, "epoch {epoch}");
            assert_eq!(full.communication, inc.communication, "epoch {epoch}");
            since = store.revision();
            prev = inc;
        }
    });
}
