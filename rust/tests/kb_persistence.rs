//! Integration: Knowledge Base persistence across pipeline generations —
//! the §4.4 "collection of JSON files" contract, memory-weight decay
//! across process restarts, and recall of still-valid constraints.

use greengen::config::scenarios;
use greengen::kb::KnowledgeBase;
use greengen::pipeline::{GeneratorPipeline, PipelineConfig};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("greengen-kbtest-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn kb_survives_restart() {
    let dir = tmp_dir("restart");
    let scenario = scenarios::scenario(1).unwrap();

    // first "process": learn + persist
    let ck_before = {
        let mut pipeline = GeneratorPipeline::new(PipelineConfig::default());
        pipeline.run_scenario(&scenario).unwrap();
        pipeline.kb.save(&dir).unwrap();
        pipeline.kb.ck.len()
    };
    assert!(ck_before > 0);

    // second "process": reload and verify identical knowledge
    let kb = KnowledgeBase::load(&dir).unwrap();
    assert_eq!(kb.ck.len(), ck_before);
    assert!(!kb.sk.is_empty());
    assert!(!kb.nk.is_empty());
    for entry in kb.ck.values() {
        assert_eq!(entry.mu, 1.0); // freshly generated
        assert!(entry.constraint.em > 0.0);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn context_switch_decays_old_constraints() {
    let dir = tmp_dir("decay");
    // learn on the EU infrastructure
    let mut pipeline = GeneratorPipeline::new(PipelineConfig::default());
    pipeline.run_scenario(&scenarios::scenario(1).unwrap()).unwrap();
    let eu_keys: Vec<String> = pipeline.kb.ck.keys().cloned().collect();
    pipeline.kb.save(&dir).unwrap();

    // resume on the US infrastructure: EU constraints are not regenerated
    let mut pipeline = GeneratorPipeline::new(PipelineConfig::default())
        .with_kb_dir(&dir)
        .unwrap();
    pipeline.run_scenario(&scenarios::scenario(2).unwrap()).unwrap();
    let decay = pipeline.config.enricher.decay;
    let mut seen_decayed = 0;
    for key in &eu_keys {
        if let Some(entry) = pipeline.kb.ck.get(key) {
            assert!((entry.mu - decay).abs() < 1e-12, "{key}: mu {}", entry.mu);
            seen_decayed += 1;
        }
    }
    assert!(seen_decayed > 0, "EU constraints should persist with decayed mu");
    // and the US ones are fresh
    assert!(pipeline
        .kb
        .ck
        .values()
        .any(|e| (e.mu - 1.0).abs() < 1e-12));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_constraints_eventually_evicted() {
    let mut pipeline = GeneratorPipeline::new(PipelineConfig::default());
    // learn EU constraints once
    pipeline.run_scenario(&scenarios::scenario(1).unwrap()).unwrap();
    let eu_ck = pipeline.kb.ck.len();
    assert!(eu_ck > 0);
    // then run the US scenario many times; EU entries decay below the
    // floor and disappear
    let us = scenarios::scenario(2).unwrap();
    for _ in 0..12 {
        pipeline.run_scenario(&us).unwrap();
    }
    for (key, entry) in &pipeline.kb.ck {
        assert!(
            entry.mu >= pipeline.config.enricher.drop_below,
            "{key} kept below floor"
        );
    }
    // all surviving constraints reference US nodes
    let us_nodes = ["washington", "california", "texas", "florida", "newyork", "arizona"];
    for entry in pipeline.kb.ck.values() {
        if let greengen::constraints::ConstraintKind::AvoidNode { node, .. } =
            &entry.constraint.kind
        {
            assert!(us_nodes.contains(&node.as_str()), "stale EU node {node} survived");
        }
    }
}

#[test]
fn kb_warm_start_recall_regenerates_after_restart() {
    let dir = tmp_dir("warmstart");
    let scenario = scenarios::scenario(1).unwrap();

    // first "process": learn profiles + constraints, persist the KB
    let keys_before: Vec<String> = {
        let mut pipeline = GeneratorPipeline::new(PipelineConfig::default());
        pipeline.run_scenario(&scenario).unwrap();
        pipeline.kb.save(&dir).unwrap();
        pipeline.kb.ck.keys().cloned().collect()
    };
    assert!(!keys_before.is_empty());

    // second "process": fresh app (profiles lost) and an EMPTY monitoring
    // store — the §3 recall path warm-starts the profiles from SK, so the
    // same constraints are regenerated with full memory weight instead of
    // merely decaying toward eviction
    let mut pipeline = GeneratorPipeline::new(PipelineConfig::default())
        .with_kb_dir(&dir)
        .unwrap();
    let mut app = scenario.app.clone();
    let mut infra = scenario.infra.clone();
    let store = greengen::monitoring::MetricStore::new();
    let outcome = pipeline
        .run_epoch(&mut app, &mut infra, &store, &scenario.intensity, 7200.0)
        .unwrap();
    assert!(!outcome.ranked.is_empty());

    let mut keys_after: Vec<String> = pipeline.kb.ck.keys().cloned().collect();
    let mut keys_expected = keys_before.clone();
    keys_after.sort();
    keys_expected.sort();
    assert_eq!(keys_after, keys_expected, "recalled constraints diverged");
    for (key, entry) in &pipeline.kb.ck {
        assert_eq!(entry.mu, 1.0, "{key} decayed despite warm-start recall");
        assert_eq!(entry.generated_at, 7200.0, "{key}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_kb_file_is_an_error_not_a_panic() {
    let dir = tmp_dir("corrupt");
    std::fs::write(dir.join("ck.json"), "{not json").unwrap();
    std::fs::write(dir.join("sk.json"), "[]").unwrap();
    std::fs::write(dir.join("ik.json"), "[]").unwrap();
    std::fs::write(dir.join("nk.json"), "[]").unwrap();
    assert!(KnowledgeBase::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
