//! Integration tests for the delta-evaluation move core and the
//! local-search solver ladder: exactness of delta-tracked scoring under
//! random move sequences, never-worse-than-greedy guarantees for
//! anneal/LNS/portfolio on continuum fleets, and exact-optimum parity on
//! the small instances branch-and-bound can ground-truth.

use greengen::constraints::{Constraint, ConstraintGenerator, GeneratorConfig};
use greengen::model::{Application, Infrastructure};
use greengen::runtime::NativeBackend;
use greengen::scheduler::{
    check_feasible, solver_by_name, AnnealScheduler, BranchAndBoundScheduler, GreedyScheduler,
    LnsScheduler, Move, Objective, PortfolioScheduler, Problem, Scheduler, ScoreState,
};
use greengen::simulate;
use greengen::util::proptest::check;
use greengen::util::Rng;

/// Random instance with generated-and-weighted green constraints (the
/// same construction `rust/tests/continuum.rs` uses).
fn instance(
    rng: &mut Rng,
    services: usize,
    nodes: usize,
) -> (Application, Infrastructure, Vec<Constraint>) {
    let app = simulate::random_application(rng, services);
    let infra = simulate::random_infrastructure(rng, nodes);
    let backend = NativeBackend;
    let mut constraints = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.7,
            use_prolog: false,
        })
        .generate(&app, &infra)
        .unwrap()
        .constraints;
    for (i, c) in constraints.iter_mut().enumerate() {
        c.weight = 0.1 + 0.05 * (i % 10) as f64;
    }
    (app, infra, constraints)
}

/// Topology fleet with constraints, at the acceptance scale (50+
/// services).
fn fleet(
    topo: simulate::Topology,
    seed: u64,
) -> (Application, Infrastructure, Vec<Constraint>) {
    let spec = simulate::TopologySpec::new(topo, 24, 56)
        .with_zones(4)
        .with_seed(seed);
    let (app, infra) = simulate::topology::generate(&spec);
    let backend = NativeBackend;
    let mut constraints = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.7,
            use_prolog: false,
        })
        .generate(&app, &infra)
        .unwrap()
        .constraints;
    for (i, c) in constraints.iter_mut().enumerate() {
        c.weight = 0.1 + 0.05 * (i % 10) as f64;
    }
    (app, infra, constraints)
}

fn objective_of(problem: &Problem, plan: &greengen::model::DeploymentPlan) -> f64 {
    problem.objective_value(&problem.to_assignment(plan).unwrap())
}

#[test]
fn property_delta_tracked_objective_equals_full_rescore() {
    check("ScoreState delta == full rescore", 24, |rng| {
        let services = 6 + rng.below(10); // 6..=15
        let nodes = 3 + rng.below(5); // 3..=7
        let (app, infra, constraints) = instance(rng, services, nodes);
        let emissions_weight = if rng.chance(0.5) { 1.0 } else { 0.0 };
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective {
                emissions_weight,
                ..Objective::default()
            },
        };
        let compiled = problem.compile();
        let mut state = ScoreState::new(&compiled, vec![None; services]);
        for _ in 0..120 {
            let mv = match rng.below(4) {
                0 => Move::Drop {
                    service: rng.below(services),
                },
                1 => Move::Swap {
                    a: rng.below(services),
                    b: rng.below(services),
                },
                _ => {
                    let si = rng.below(services);
                    Move::Reassign {
                        service: si,
                        flavour: rng.below(app.services[si].flavours.len()),
                        node: rng.below(nodes),
                    }
                }
            };
            // occasionally exercise undo as well
            if rng.chance(0.2) {
                if state.delta(mv).is_some() {
                    // delta must be side-effect free
                    assert!((state.objective() - state.rescore()).abs() < 1e-9);
                }
            } else {
                state.apply(mv);
            }
            assert!(
                (state.objective() - state.rescore()).abs() < 1e-9,
                "tracked {} vs rescore {}",
                state.objective(),
                state.rescore()
            );
        }
    });
}

#[test]
fn property_portfolio_never_worse_than_greedy() {
    check("portfolio <= greedy", 10, |rng| {
        let services = 12 + rng.below(20); // 12..=31
        let nodes = 5 + rng.below(8); // 5..=12
        let (app, infra, constraints) = instance(rng, services, nodes);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        let greedy = GreedyScheduler::default().schedule(&problem);
        let portfolio = PortfolioScheduler::seeded(rng.next_u64()).schedule(&problem);
        match (greedy, portfolio) {
            (Ok(g), Ok(p)) => {
                check_feasible(&problem, &p).unwrap();
                let vg = objective_of(&problem, &g);
                let vp = objective_of(&problem, &p);
                assert!(vp <= vg + 1e-9, "portfolio {vp} worse than greedy {vg}");
            }
            (Err(_), _) => {} // knife-edge instance: nothing to compare
            (Ok(_), Err(e)) => panic!("greedy feasible but portfolio failed: {e}"),
        }
    });
}

#[test]
fn ladder_feasible_and_never_worse_than_greedy_on_every_topology() {
    for topo in simulate::Topology::ALL {
        let (app, infra, constraints) = fleet(topo, 0x1ADDE2);
        assert!(app.services.len() >= 50);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        let greedy = GreedyScheduler::default().schedule(&problem).unwrap();
        let vg = objective_of(&problem, &greedy);
        for name in ["anneal", "lns", "portfolio"] {
            let solver = solver_by_name(name, 0xBEEF).unwrap();
            let plan = solver.schedule(&problem).unwrap();
            check_feasible(&problem, &plan)
                .unwrap_or_else(|e| panic!("{}/{name}: infeasible: {e}", topo.name()));
            let v = objective_of(&problem, &plan);
            assert!(
                v <= vg + 1e-9,
                "{}/{name}: objective {v} worse than greedy {vg}",
                topo.name()
            );
        }
    }
}

#[test]
fn local_search_solvers_match_branch_and_bound_on_small_parity_instances() {
    // mirrors the exact-delegate parity fixtures in rust/tests/continuum.rs
    let mut rng = Rng::new(0x7A217);
    for _ in 0..5 {
        let (app, infra, constraints) = instance(&mut rng, 5, 4);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        let exact = BranchAndBoundScheduler::default().schedule(&problem);
        for solver in [
            Box::new(AnnealScheduler::seeded(1)) as Box<dyn Scheduler>,
            Box::new(LnsScheduler::seeded(2)),
            Box::new(PortfolioScheduler::seeded(3)),
        ] {
            match (&exact, solver.schedule(&problem)) {
                (Ok(e), Ok(p)) => {
                    // tiny instances delegate to the very same exact
                    // solver: identical plans, identical optimum
                    assert_eq!(*e, p, "{} diverged from BnB", solver.name());
                    let ve = objective_of(&problem, e);
                    let vp = objective_of(&problem, &p);
                    assert!((ve - vp).abs() < 1e-9);
                }
                (Err(_), Err(_)) => {}
                (a, b) => panic!(
                    "feasibility disagreement: exact {:?} vs {} {:?}",
                    a.is_ok(),
                    solver.name(),
                    b.is_ok()
                ),
            }
        }
    }
}

#[test]
fn bnb_still_optimal_after_delta_refactor() {
    // greedy can never beat the exact solver if the incremental lower
    // bound is admissible and leaf values are tracked exactly
    let mut rng = Rng::new(0xB0B0);
    for _ in 0..8 {
        let (app, infra, constraints) = instance(&mut rng, 4, 3);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        let exact = BranchAndBoundScheduler::default().schedule(&problem);
        let greedy = GreedyScheduler::default().schedule(&problem);
        if let (Ok(e), Ok(g)) = (exact, greedy) {
            let ve = objective_of(&problem, &e);
            let vg = objective_of(&problem, &g);
            assert!(ve <= vg + 1e-9, "exact {ve} worse than greedy {vg}");
        }
    }
}
