//! Black-box CLI tests: run the built `greengen` binary as a subprocess
//! and check its contract (exit codes, output formats, error handling).

use std::process::Command;

fn greengen(args: &[&str]) -> (String, String, bool) {
    let exe = env!("CARGO_BIN_EXE_greengen");
    let out = Command::new(exe)
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = greengen(&["help"]);
    assert!(ok);
    for cmd in [
        "scenario",
        "generate",
        "adaptive",
        "schedule",
        "scalability",
        "threshold",
        "timeshift",
        "forecast",
        "continuum",
    ] {
        assert!(stdout.contains(cmd), "{cmd} missing from usage");
    }
}

#[test]
fn forecast_reports_blended_accuracy_win() {
    let (stdout, stderr, ok) = greengen(&["forecast", "--scenario", "3", "--horizon", "6"]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("seasonal-naive"), "{stdout}");
    assert!(stdout.contains("ewma-drift"), "{stdout}");
    assert!(stdout.contains("blended"), "{stdout}");
    // the acceptance criterion: blended MAPE below seasonal-naive on the
    // Scenario 3 trace (the improvement line names the winner)
    assert!(stdout.contains("(blended better)"), "{stdout}");
}

#[test]
fn adaptive_horizon_prints_projection() {
    let (stdout, stderr, ok) = greengen(&[
        "adaptive",
        "--scenario",
        "3",
        "--hours",
        "12",
        "--regen",
        "6",
        "--horizon",
        "6",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("projected_g"), "{stdout}");
    assert!(stdout.contains("forecast-projected emissions"), "{stdout}");
}

#[test]
fn continuum_compares_solvers_and_replans() {
    let (stdout, stderr, ok) = greengen(&[
        "continuum",
        "--topology",
        "geo-regions",
        "--nodes",
        "48",
        "--services",
        "96",
        "--zones",
        "4",
        "--epochs",
        "3",
        "--seed",
        "7",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("monolithic-greedy"), "{stdout}");
    assert!(stdout.contains("sharded-continuum"), "{stdout}");
    assert!(stdout.contains("speedup"), "{stdout}");
    // the incremental demo reports per-epoch dirty-zone counts
    assert!(stdout.contains("dirty"), "{stdout}");
}

#[test]
fn continuum_rejects_unknown_topology() {
    let (_, stderr, ok) = greengen(&["continuum", "--topology", "moonbase", "--nodes", "8"]);
    assert!(!ok);
    assert!(stderr.contains("unknown topology"));
}

#[test]
fn scenario1_prints_paper_constraints() {
    let (stdout, _, ok) = greengen(&["scenario", "1"]);
    assert!(ok);
    assert!(stdout.contains("avoidNode(d(frontend, large), italy, 1.000)."));
    assert!(stdout.contains("avoidNode(d(frontend, large), greatbritain, 0.6"));
}

#[test]
fn scenario_json_format_parses() {
    let (stdout, _, ok) = greengen(&["scenario", "1", "--format", "json"]);
    assert!(ok);
    let json_start = stdout.find('[').unwrap();
    let v = greengen::jsonio::parse(&stdout[json_start..]).unwrap();
    assert!(!v.as_array().unwrap().is_empty());
}

#[test]
fn scenario_explain_flag_adds_report() {
    let (stdout, _, ok) = greengen(&["scenario", "1", "--explain"]);
    assert!(ok);
    assert!(stdout.contains("estimated emissions savings"));
}

#[test]
fn invalid_inputs_fail_cleanly() {
    let (_, stderr, ok) = greengen(&["scenario", "9"]);
    assert!(!ok);
    assert!(stderr.contains("unknown scenario"));

    let (_, stderr, ok) = greengen(&["scenario", "1", "--bogus-flag"]);
    assert!(!ok);
    assert!(stderr.contains("unknown option"));

    let (_, stderr, ok) = greengen(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn adaptive_short_run_reports_reduction() {
    let (stdout, _, ok) = greengen(&["adaptive", "--hours", "6", "--regen", "6"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("emission reduction vs cost-only"));
}

#[test]
fn schedule_emits_plan_and_metrics() {
    let (stdout, _, ok) = greengen(&["schedule", "--scenario", "1"]);
    assert!(ok);
    assert!(stdout.contains("deploy frontend"));
    assert!(stdout.contains("emissions="));
}

#[test]
fn schedule_accepts_local_search_solvers() {
    for solver in ["anneal", "lns", "portfolio"] {
        let (stdout, stderr, ok) =
            greengen(&["schedule", "--scenario", "1", "--solver", solver, "--seed", "5"]);
        assert!(ok, "{solver}: {stderr}");
        assert!(stdout.contains(&format!("solver={solver}")), "{stdout}");
        assert!(stdout.contains("deploy frontend"), "{stdout}");
    }
    let (_, stderr, ok) = greengen(&["schedule", "--solver", "quantum"]);
    assert!(!ok);
    assert!(stderr.contains("unknown solver"), "{stderr}");
}

#[test]
fn timeshift_recommends_window() {
    let (stdout, _, ok) = greengen(&["timeshift"]);
    assert!(ok);
    assert!(stdout.contains("timeShift(d(email, tiny)"));
}

#[test]
fn generate_from_files_round_trips() {
    // write app/infra JSON via the library, feed them back through the CLI
    let dir = std::env::temp_dir().join(format!("greengen-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut app = greengen::config::boutique::application();
    // pre-enrich profiles (the CLI's generate path reads them from file)
    for (service, flavour, wh, _, _) in greengen::config::boutique::TABLE1 {
        app.service_mut(service)
            .unwrap()
            .flavour_mut(flavour)
            .unwrap()
            .energy = Some(greengen::model::EnergyProfile {
            kwh: wh / 1000.0,
            samples: 1,
        });
    }
    let infra = greengen::config::boutique::eu_infrastructure();
    let app_path = dir.join("app.json");
    let infra_path = dir.join("infra.json");
    greengen::jsonio::to_file(&app_path, &app.to_json()).unwrap();
    greengen::jsonio::to_file(&infra_path, &infra.to_json()).unwrap();

    let (stdout, stderr, ok) = greengen(&[
        "generate",
        "--app",
        app_path.to_str().unwrap(),
        "--infra",
        infra_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    // analytic profiles: frontend/large on italy tops the ranking
    assert!(stdout.contains("avoidNode(d(frontend, large), italy, 1.000)."));
    std::fs::remove_dir_all(&dir).ok();
}
