//! Black-box CLI tests: run the built `greengen` binary as a subprocess
//! and check its contract (exit codes, output formats, error handling).

use std::process::Command;

fn greengen(args: &[&str]) -> (String, String, bool) {
    let exe = env!("CARGO_BIN_EXE_greengen");
    let out = Command::new(exe)
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

/// Keeps `docs/cli.md` honest: its `## \`greengen <cmd>\`` headings must
/// match the usage screen exactly, and every documented subcommand must
/// be accepted by the arg parser (a rejected *option* proves the command
/// routed — an unknown command fails with "unknown command" instead).
#[test]
fn cli_doc_headings_match_parser() {
    let doc = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/docs/cli.md"))
        .expect("docs/cli.md");
    let documented: std::collections::BTreeSet<String> = doc
        .lines()
        .filter_map(|l| l.strip_prefix("## `greengen "))
        .map(|l| {
            l.trim_end()
                .trim_end_matches('`')
                .split_whitespace()
                .next()
                .unwrap()
                .to_string()
        })
        .collect();
    assert!(!documented.is_empty(), "no `## \\`greengen <cmd>\\`` headings found");

    let (usage, _, ok) = greengen(&["help"]);
    assert!(ok);
    let advertised: std::collections::BTreeSet<String> = usage
        .lines()
        .filter_map(|l| l.trim_start().strip_prefix("greengen "))
        .filter_map(|rest| rest.split_whitespace().next())
        // drop the banner line ("greengen — Green by Design ...");
        // subcommand names are alphanumeric-or-hyphen (e.g. obs-summary)
        .filter(|token| {
            token
                .chars()
                .all(|ch| ch.is_ascii_alphanumeric() || ch == '-')
        })
        .map(str::to_string)
        .collect();
    assert_eq!(
        documented, advertised,
        "docs/cli.md headings out of sync with the greengen usage screen"
    );

    for cmd in &documented {
        if cmd == "info" {
            // takes no options; accepted iff it runs
            let (_, stderr, ok) = greengen(&[cmd.as_str()]);
            assert!(ok, "{cmd}: {stderr}");
            continue;
        }
        let (_, stderr, ok) = greengen(&[cmd.as_str(), "--definitely-not-an-option"]);
        assert!(!ok, "{cmd} accepted a bogus option");
        assert!(
            stderr.contains("unknown option"),
            "{cmd} is documented but not routed by the parser: {stderr}"
        );
    }
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = greengen(&["help"]);
    assert!(ok);
    for cmd in [
        "scenario",
        "generate",
        "adaptive",
        "schedule",
        "scalability",
        "threshold",
        "timeshift",
        "forecast",
        "continuum",
    ] {
        assert!(stdout.contains(cmd), "{cmd} missing from usage");
    }
}

#[test]
fn forecast_reports_blended_accuracy_win() {
    let (stdout, stderr, ok) = greengen(&["forecast", "--scenario", "3", "--horizon", "6"]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("seasonal-naive"), "{stdout}");
    assert!(stdout.contains("ewma-drift"), "{stdout}");
    assert!(stdout.contains("blended"), "{stdout}");
    // the acceptance criterion: blended MAPE below seasonal-naive on the
    // Scenario 3 trace (the improvement line names the winner)
    assert!(stdout.contains("(blended better)"), "{stdout}");
}

#[test]
fn adaptive_horizon_prints_projection() {
    let (stdout, stderr, ok) = greengen(&[
        "adaptive",
        "--scenario",
        "3",
        "--hours",
        "12",
        "--regen",
        "6",
        "--horizon",
        "6",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("projected_g"), "{stdout}");
    assert!(stdout.contains("forecast-projected emissions"), "{stdout}");
}

#[test]
fn continuum_compares_solvers_and_replans() {
    let (stdout, stderr, ok) = greengen(&[
        "continuum",
        "--topology",
        "geo-regions",
        "--nodes",
        "48",
        "--services",
        "96",
        "--zones",
        "4",
        "--epochs",
        "3",
        "--seed",
        "7",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("monolithic-greedy"), "{stdout}");
    assert!(stdout.contains("sharded-continuum"), "{stdout}");
    assert!(stdout.contains("speedup"), "{stdout}");
    // the incremental demo reports per-epoch dirty-zone counts
    assert!(stdout.contains("dirty"), "{stdout}");
}

#[test]
fn continuum_rejects_unknown_topology() {
    let (_, stderr, ok) = greengen(&["continuum", "--topology", "moonbase", "--nodes", "8"]);
    assert!(!ok);
    assert!(stderr.contains("unknown topology"));
}

#[test]
fn scenario1_prints_paper_constraints() {
    let (stdout, _, ok) = greengen(&["scenario", "1"]);
    assert!(ok);
    assert!(stdout.contains("avoidNode(d(frontend, large), italy, 1.000)."));
    assert!(stdout.contains("avoidNode(d(frontend, large), greatbritain, 0.6"));
}

#[test]
fn scenario_json_format_parses() {
    let (stdout, _, ok) = greengen(&["scenario", "1", "--format", "json"]);
    assert!(ok);
    let json_start = stdout.find('[').unwrap();
    let v = greengen::jsonio::parse(&stdout[json_start..]).unwrap();
    assert!(!v.as_array().unwrap().is_empty());
}

#[test]
fn scenario_explain_flag_adds_report() {
    let (stdout, _, ok) = greengen(&["scenario", "1", "--explain"]);
    assert!(ok);
    assert!(stdout.contains("estimated emissions savings"));
}

#[test]
fn invalid_inputs_fail_cleanly() {
    let (_, stderr, ok) = greengen(&["scenario", "9"]);
    assert!(!ok);
    assert!(stderr.contains("unknown scenario"));

    let (_, stderr, ok) = greengen(&["scenario", "1", "--bogus-flag"]);
    assert!(!ok);
    assert!(stderr.contains("unknown option"));

    let (_, stderr, ok) = greengen(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn adaptive_short_run_reports_reduction() {
    let (stdout, _, ok) = greengen(&["adaptive", "--hours", "6", "--regen", "6"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("emission reduction vs cost-only"));
}

#[test]
fn adaptive_incremental_reports_row_telemetry() {
    let (stdout, stderr, ok) = greengen(&[
        "adaptive",
        "--hours",
        "12",
        "--regen",
        "6",
        "--incremental",
        "--zones",
        "2",
    ]);
    assert!(ok, "{stderr}");
    // per-epoch constraint-generation dirty-row counts are in the log
    assert!(stdout.contains("rows(dirty/total)"), "{stdout}");
    assert!(stdout.contains("zones(dirty/total)"), "{stdout}");
}

#[test]
fn schedule_emits_plan_and_metrics() {
    let (stdout, _, ok) = greengen(&["schedule", "--scenario", "1"]);
    assert!(ok);
    assert!(stdout.contains("deploy frontend"));
    assert!(stdout.contains("emissions="));
}

#[test]
fn schedule_accepts_local_search_solvers() {
    for solver in ["anneal", "lns", "portfolio"] {
        let (stdout, stderr, ok) =
            greengen(&["schedule", "--scenario", "1", "--solver", solver, "--seed", "5"]);
        assert!(ok, "{solver}: {stderr}");
        assert!(stdout.contains(&format!("solver={solver}")), "{stdout}");
        assert!(stdout.contains("deploy frontend"), "{stdout}");
    }
    let (_, stderr, ok) = greengen(&["schedule", "--solver", "quantum"]);
    assert!(!ok);
    assert!(stderr.contains("unknown solver"), "{stderr}");
}

#[test]
fn timeshift_recommends_window() {
    let (stdout, _, ok) = greengen(&["timeshift"]);
    assert!(ok);
    assert!(stdout.contains("timeShift(d(email, tiny)"));
}

#[test]
fn generate_from_files_round_trips() {
    // write app/infra JSON via the library, feed them back through the CLI
    let dir = std::env::temp_dir().join(format!("greengen-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut app = greengen::config::boutique::application();
    // pre-enrich profiles (the CLI's generate path reads them from file)
    for (service, flavour, wh, _, _) in greengen::config::boutique::TABLE1 {
        app.service_mut(service)
            .unwrap()
            .flavour_mut(flavour)
            .unwrap()
            .energy = Some(greengen::model::EnergyProfile {
            kwh: wh / 1000.0,
            samples: 1,
        });
    }
    let infra = greengen::config::boutique::eu_infrastructure();
    let app_path = dir.join("app.json");
    let infra_path = dir.join("infra.json");
    greengen::jsonio::to_file(&app_path, &app.to_json()).unwrap();
    greengen::jsonio::to_file(&infra_path, &infra.to_json()).unwrap();

    let (stdout, stderr, ok) = greengen(&[
        "generate",
        "--app",
        app_path.to_str().unwrap(),
        "--infra",
        infra_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    // analytic profiles: frontend/large on italy tops the ranking
    assert!(stdout.contains("avoidNode(d(frontend, large), italy, 1.000)."));

    // --incremental: epoch 0 is the cold full pass, epoch 1 reuses
    // everything (same files, nothing changed) — and the constraints are
    // the same as the full run above
    let (stdout2, stderr2, ok) = greengen(&[
        "generate",
        "--app",
        app_path.to_str().unwrap(),
        "--infra",
        infra_path.to_str().unwrap(),
        "--incremental",
        "--epochs",
        "2",
    ]);
    assert!(ok, "{stderr2}");
    // telemetry on stderr; stdout stays machine-readable
    assert!(stderr2.contains("full_rebuild true"), "{stderr2}");
    assert!(stderr2.contains("dirty_rows 0/"), "{stderr2}");
    assert!(
        stdout2.contains("avoidNode(d(frontend, large), italy, 1.000)."),
        "{stdout2}"
    );
    assert!(!stdout2.contains("# epoch"), "{stdout2}");
    std::fs::remove_dir_all(&dir).ok();
}
