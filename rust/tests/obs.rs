//! Observability-layer integration tests: the Prometheus exposition
//! round-trip, the JSONL trace schema emitted by `--trace`, and the
//! contract that the disabled path records nothing and changes no
//! output. Everything that *enables* the global collectors runs the
//! built binary as a subprocess — `cargo test` runs in-process tests on
//! parallel threads, and the obs globals are process-wide.

use greengen::obs::metrics::Registry;
use greengen::obs::trace;
use greengen::util::proptest::check;
use std::process::Command;

fn greengen(args: &[&str]) -> (String, String, bool) {
    let exe = env!("CARGO_BIN_EXE_greengen");
    let out = Command::new(exe).args(args).output().expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("greengen-obs-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------------- metrics

#[test]
fn exposition_round_trips_for_random_registries() {
    check("render/parse/render is the identity", 64, |rng| {
        let r = Registry::default();
        let names = ["greengen_sched_a_total", "greengen_sched_b_total"];
        for _ in 0..(1 + rng.below(8)) {
            let name = names[rng.below(names.len())];
            let label_value = format!("v{}", rng.below(4));
            r.counter_add(name, &[("solver", label_value.as_str())], rng.range(0.0, 1e6));
        }
        for _ in 0..(1 + rng.below(4)) {
            r.gauge_set("greengen_sched_temp", &[], rng.range(-50.0, 50.0));
        }
        for _ in 0..(1 + rng.below(16)) {
            r.histogram_observe("greengen_sched_lat_ms", &[], rng.range(0.0, 20_000.0));
        }
        let text = r.render(1_717_000_000_000);
        let back = Registry::from_exposition(&text).expect("own output parses");
        assert_eq!(back.render(1_717_000_000_000), text);
    });
}

#[test]
fn exposition_survives_awkward_label_values() {
    let r = Registry::default();
    r.counter_add(
        "greengen_sched_moves_total",
        &[("zone", "eu \"west\"\nline\\slash")],
        3.0,
    );
    let text = r.render(7);
    let back = Registry::from_exposition(&text).unwrap();
    assert_eq!(back.render(7), text);
}

// ------------------------------------------------------------------ trace

/// Every `--trace` line is one span object with the pinned field set
/// and types; ids are unique, parents resolve, and child spans nest
/// inside their parent's duration.
#[test]
fn trace_flag_writes_schema_conformant_jsonl() {
    let dir = temp_dir("schema");
    let tpath = dir.join("trace.jsonl");
    let mpath = dir.join("metrics.prom");
    let (stdout, stderr, ok) = greengen(&[
        "schedule",
        "--scenario",
        "1",
        "--seed",
        "5",
        "--trace",
        tpath.to_str().unwrap(),
        "--metrics",
        mpath.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("deploy frontend"), "{stdout}");

    let text = std::fs::read_to_string(&tpath).unwrap();
    let mut ids = std::collections::BTreeSet::new();
    let mut n_lines = 0usize;
    for line in text.lines() {
        n_lines += 1;
        let v = greengen::jsonio::parse(line).expect("trace line parses");
        let obj = v.as_object().expect("span is an object");
        let field = |k: &str| {
            obj.iter()
                .find(|(key, _)| key == k)
                .map(|(_, val)| val)
                .unwrap_or_else(|| panic!("missing field '{k}' in {line}"))
        };
        assert!(field("span").as_str().is_some(), "{line}");
        let id = field("id").as_f64().expect("id is a number") as u64;
        assert!(id > 0);
        assert!(ids.insert(id), "duplicate span id {id}");
        let parent = field("parent");
        assert!(
            parent.as_f64().is_some() || matches!(parent, &greengen::jsonio::Value::Null),
            "{line}"
        );
        assert!(field("thread").as_f64().is_some(), "{line}");
        assert!(field("start_us").as_f64().is_some(), "{line}");
        assert!(field("dur_us").as_f64().is_some(), "{line}");
        assert!(field("attrs").as_object().is_some(), "{line}");
    }
    assert!(n_lines > 0, "trace is empty");

    // the library reader agrees line-for-line with the raw parse
    let records = trace::read_jsonl(&tpath).unwrap();
    assert_eq!(records.len(), n_lines);

    // the schedule path records its stages
    let names: std::collections::BTreeSet<&str> =
        records.iter().map(|r| r.name.as_str()).collect();
    assert!(names.contains("problem.compile"), "{names:?}");
    assert!(names.contains("greedy.construct"), "{names:?}");
    assert!(names.contains("meter.stage"), "{names:?}");

    // nesting: a parent's duration covers the sum of its children
    // (microsecond truncation can leave ±1us per child)
    let by_id: std::collections::BTreeMap<u64, &trace::SpanRecord> =
        records.iter().map(|r| (r.id, r)).collect();
    let mut child_us: std::collections::BTreeMap<u64, (u64, u64)> =
        std::collections::BTreeMap::new();
    for r in &records {
        if r.parent != 0 {
            assert!(by_id.contains_key(&r.parent), "dangling parent {}", r.parent);
            let e = child_us.entry(r.parent).or_insert((0, 0));
            e.0 += r.dur_us;
            e.1 += 1;
        }
    }
    for (pid, (sum, n)) in child_us {
        let parent = by_id[&pid];
        assert!(
            sum <= parent.dur_us + n + 2,
            "children of '{}' ({sum}us) exceed the span itself ({}us)",
            parent.name,
            parent.dur_us
        );
    }

    // aggregate() folds the same trace into per-stage totals
    let stats = trace::aggregate(&records);
    assert!(stats.iter().any(|s| s.name == "greedy.construct"));
    let total: usize = stats.iter().map(|s| s.count).sum();
    assert_eq!(total, records.len());

    // the exported metrics re-ingest through the repo's own parser
    let prom = std::fs::read_to_string(&mpath).unwrap();
    let reg = Registry::from_exposition(&prom).unwrap();
    assert!(reg.series_count() > 0);
    assert!(
        reg.counter_value("greengen_sched_compile_total", &[]).unwrap_or(0.0) >= 1.0,
        "{prom}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// `--trace`/`--metrics` must not perturb stdout by a single byte: the
/// report is the same with and without instrumentation (status lines go
/// to stderr).
#[test]
fn trace_flags_leave_stdout_byte_identical() {
    let dir = temp_dir("ident");
    let (plain, _, ok) = greengen(&["schedule", "--scenario", "1", "--seed", "5"]);
    assert!(ok);
    let (traced, _, ok) = greengen(&[
        "schedule",
        "--scenario",
        "1",
        "--seed",
        "5",
        "--trace",
        dir.join("t.jsonl").to_str().unwrap(),
        "--metrics",
        dir.join("m.prom").to_str().unwrap(),
    ]);
    assert!(ok);
    assert_eq!(plain, traced, "instrumentation changed the report");
    std::fs::remove_dir_all(&dir).ok();
}

/// With the collectors off (the default), a full scheduling run through
/// the instrumented layers records nothing at all — no spans, no metric
/// series.
#[test]
fn disabled_path_records_nothing() {
    assert!(!trace::enabled());
    assert!(!greengen::obs::metrics::enabled());

    let scenario = greengen::config::scenarios::scenario(1).unwrap();
    let mut pipe = greengen::pipeline::GeneratorPipeline::new(Default::default());
    let outcome = pipe.run_scenario(&scenario).unwrap();
    let problem = greengen::scheduler::Problem {
        app: &scenario.app,
        infra: &scenario.infra,
        constraints: &outcome.ranked,
        objective: greengen::scheduler::Objective::default(),
    };
    for solver in ["greedy", "anneal", "lns", "exact"] {
        let s = greengen::scheduler::solver_by_name(solver, 5).unwrap();
        s.schedule(&problem).unwrap();
    }

    assert!(trace::drain().is_empty(), "spans recorded while disabled");
    assert!(
        greengen::obs::metrics::global().is_empty(),
        "metric series recorded while disabled"
    );
}

// --------------------------------------------------------- adaptive table

/// Golden pin for the adaptive report's column layout: every data row
/// must be exactly what the pre-observability format string produced
/// for its values.
#[test]
fn adaptive_table_layout_is_pinned() {
    let (stdout, stderr, ok) = greengen(&["adaptive", "--hours", "12", "--regen", "6"]);
    assert!(ok, "{stderr}");
    let mut lines = stdout.lines();
    assert_eq!(
        lines.next().unwrap(),
        "hour  #constraints  constrained_g  cost_only_g  random_g  oracle_g  failed"
    );
    let mut rows = 0usize;
    for line in lines {
        if line.is_empty() {
            break; // totals block follows the table
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        assert_eq!(f.len(), 7, "unexpected row shape: {line}");
        let rebuilt = format!(
            "{:>4}  {:>12}  {:>13.1}  {:>11.1}  {:>8.1}  {:>8.1}  {}",
            f[0].parse::<usize>().unwrap(),
            f[1].parse::<usize>().unwrap(),
            f[2].parse::<f64>().unwrap(),
            f[3].parse::<f64>().unwrap(),
            f[4].parse::<f64>().unwrap(),
            f[5].parse::<f64>().unwrap(),
            f[6],
        );
        assert_eq!(line, rebuilt, "column layout drifted");
        rows += 1;
    }
    assert_eq!(rows, 2, "{stdout}");
}

// ------------------------------------------------------------ obs-summary

#[test]
fn obs_summary_aggregates_a_recorded_trace() {
    let dir = temp_dir("summary");
    let tpath = dir.join("trace.jsonl");
    let mpath = dir.join("metrics.prom");
    let (_, stderr, ok) = greengen(&[
        "adaptive",
        "--hours",
        "12",
        "--regen",
        "6",
        "--trace",
        tpath.to_str().unwrap(),
        "--metrics",
        mpath.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    let (stdout, stderr, ok) = greengen(&[
        "obs-summary",
        tpath.to_str().unwrap(),
        "--metrics",
        mpath.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("stage"), "{stdout}");
    assert!(stdout.contains("adaptive.epoch"), "{stdout}");
    assert!(stdout.contains("spans across"), "{stdout}");
    assert!(stdout.contains("series re-ingested"), "{stdout}");

    // bad inputs fail cleanly
    let (_, stderr, ok) = greengen(&["obs-summary"]);
    assert!(!ok);
    assert!(stderr.contains("trace file required"), "{stderr}");
    std::fs::remove_dir_all(&dir).ok();
}
