//! Integration tests for the forecasting subsystem and horizon-aware
//! temporal scheduling: the blended model must beat the seasonal-naive
//! baseline across the Scenario 3 dynamic, and a forecast-aware plan's
//! projected emissions must never exceed the reactive plan's (property
//! tested on random instances over diurnal traces).

use greengen::carbon::{CarbonIntensitySource, DiurnalTrace};
use greengen::config::scenarios;
use greengen::forecast::{
    walk_forward, AccuracyConfig, BlendedForecaster, CarbonForecaster, EwmaDrift, SeasonalNaive,
};
use greengen::model::Infrastructure;
use greengen::pipeline::{AdaptiveConfig, AdaptiveLoop, PipelineConfig};
use greengen::scheduler::{
    GreedyScheduler, Objective, Problem, Scheduler, TemporalConfig, TemporalScheduler,
};
use greengen::simulate;
use greengen::util::proptest::check;

/// The acceptance benchmark: blended MAPE below seasonal-naive on the
/// Scenario 3 diurnal trace with its France brown-out as a temporal
/// event (the same setup `greengen forecast` reports).
#[test]
fn blended_beats_seasonal_naive_on_scenario3() {
    let (before, after) = scenarios::event_trace_sets(3).unwrap();
    let event = 72.0 * 3600.0;
    let truth = |region: &str, t: f64| {
        if t < event {
            before.intensity(region, t)
        } else {
            after.intensity(region, t)
        }
    };
    let mut seasonal = SeasonalNaive::diurnal();
    let mut ewma = EwmaDrift::new();
    let mut blended = BlendedForecaster::new();
    let config = AccuracyConfig {
        train_hours: 48,
        eval_hours: 48,
        horizon_hours: 6,
        step_hours: 1,
    };
    let report = walk_forward(
        truth,
        &["FR", "ES", "DE", "GB", "IT"],
        &config,
        &mut [&mut seasonal, &mut ewma, &mut blended],
    );
    let s = report.case("seasonal-naive").unwrap();
    let b = report.case("blended").unwrap();
    assert!(s.samples > 0 && b.samples > 0);
    assert!(
        b.mape < s.mape,
        "blended MAPE {:.2}% must beat seasonal-naive {:.2}% on Scenario 3",
        b.mape,
        s.mape
    );
}

/// Train a blended forecaster on two days of per-region diurnal traces
/// derived from the infrastructure's enriched carbon values.
fn trained_on_diurnal(infra: &Infrastructure, seed: u64) -> BlendedForecaster {
    let mut f = BlendedForecaster::new();
    for n in &infra.nodes {
        let trace = DiurnalTrace::new(n.carbon().max(50.0), 0.4, 0.02, seed);
        for h in 0..48 {
            let t = h as f64 * 3600.0;
            f.observe(&n.region, t, trace.at(t));
        }
    }
    f
}

/// Property: on any instance with deferrable services over a diurnal
/// trace, the forecast-aware temporal plan projects no more emissions
/// than the reactive plan — the monotone-improvement guarantee of the
/// temporal pass.
#[test]
fn property_forecast_aware_projection_is_never_worse() {
    check("temporal projection dominance", 24, |rng| {
        let services = 8 + rng.below(13); // 8..=20
        let nodes = 4 + rng.below(7); // 4..=10
        let mut app = simulate::random_application(rng, services);
        let mut infra = simulate::random_infrastructure(rng, nodes);
        for n in &mut infra.nodes {
            n.capabilities.cpu *= 2.0; // headroom: quality, not knife-edge
            n.capabilities.ram_gb *= 2.0;
        }
        // every third service is batch-deferrable
        for (i, s) in app.services.iter_mut().enumerate() {
            if i % 3 == 0 {
                s.batch = true;
            }
        }
        let forecaster = trained_on_diurnal(&infra, rng.next_u64());
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &[],
            objective: Objective::default(),
        };
        let Ok(base) = GreedyScheduler::default().schedule(&problem) else {
            return; // infeasible random instance: property vacuous
        };
        let t0 = 47.0 * 3600.0;
        let refine = |slots: usize| {
            TemporalScheduler {
                forecaster: &forecaster,
                t0,
                config: TemporalConfig {
                    slot_hours: 1.0,
                    horizon_slots: slots,
                    max_rounds: 4,
                },
            }
            .refine(&problem, &base)
            .unwrap()
        };
        let reactive = refine(0);
        let aware = refine(12);
        assert!(
            aware.projected_g <= reactive.projected_g + 1e-9,
            "aware {:.2} > reactive {:.2} ({services} svc x {nodes} nodes)",
            aware.projected_g,
            reactive.projected_g
        );
        // reactive pass is the identity on the plan
        assert_eq!(reactive.plan, base);
    });
}

/// End-to-end acceptance: `adaptive --horizon 6` on the Scenario 3
/// trace projects no more emissions than the reactive run.
#[test]
fn adaptive_horizon6_projects_no_worse_than_reactive() {
    let scenario = scenarios::scenario(3).unwrap();
    let run = |horizon: usize| {
        let mut looper = AdaptiveLoop::new(
            PipelineConfig::default(),
            AdaptiveConfig {
                hours: 24,
                regen_every: 6,
                horizon,
                ..Default::default()
            },
        );
        looper.run(&scenario).unwrap()
    };
    let reactive = run(0);
    let aware = run(6);
    assert!(reactive.total_projected_g > 0.0);
    assert!(
        aware.total_projected_g <= reactive.total_projected_g + 1e-6,
        "horizon-6 projection {:.1} must not exceed reactive {:.1}",
        aware.total_projected_g,
        reactive.total_projected_g
    );
}
