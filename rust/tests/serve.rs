//! Black-box tests for `greengen serve`: drive the daemon as a
//! subprocess over scripted event files (`--replay`) and over live
//! stdin, and check the response-stream contract — JSONL schema, plan
//! feasibility, byte-identical replays, fault-injection accounting, and
//! the burst → incremental degradation ladder with its deadline.

use greengen::config::scenarios;
use greengen::jsonio;
use greengen::model::DeploymentPlan;
use greengen::scheduler::{check_feasible, Objective, Problem};
use std::io::Write as _;
use std::process::{Command, Stdio};

/// Stated deadline tolerance for the degradation test: the wall budget
/// bounds the *solvers*; generation, evaluation and I/O around them are
/// unbudgeted, and CI machines are slow — so epochs must land within
/// `--deadline-ms` plus this slack.
const TOLERANCE_MS: f64 = 1500.0;

fn greengen(args: &[&str]) -> (String, String, bool) {
    let exe = env!("CARGO_BIN_EXE_greengen");
    let out = Command::new(exe).args(args).output().expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

fn write_fixture(name: &str, content: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("greengen-serve-tests-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    std::fs::write(&path, content).unwrap();
    path
}

/// A calm three-epoch script: monitoring, a carbon override, node
/// churn, one plan request and one replan request.
fn calm_script() -> String {
    [
        r#"{"type":"metric_energy","t":3600,"service":"frontend","flavour":"large","joules":252000}"#,
        r#"{"type":"metric_energy","t":3600,"service":"checkout","flavour":"large","joules":72000}"#,
        r#"{"type":"metric_traffic","t":3600,"from":"frontend","from_flavour":"large","to":"checkout","requests":120,"bytes":480000}"#,
        r#"{"type":"carbon","region":"FR","intensity":40}"#,
        r#"{"type":"request","id":"r1","kind":"plan"}"#,
        r#"{"type":"tick","t":3600}"#,
        r#"{"type":"metric_energy","t":7200,"service":"frontend","flavour":"large","joules":250000}"#,
        r#"{"type":"node_down","node":"france"}"#,
        r#"{"type":"tick","t":7200}"#,
        r#"{"type":"node_up","node":"france"}"#,
        r#"{"type":"request","id":"r2","kind":"replan"}"#,
        r#"{"type":"tick","t":10800}"#,
        r#"{"type":"shutdown"}"#,
        "",
    ]
    .join("\n")
}

#[test]
fn replay_is_deterministic_with_valid_schema_and_feasible_plans() {
    let path = write_fixture("calm.jsonl", &calm_script());
    let path = path.to_str().unwrap();
    let (out_a, err_a, ok_a) = greengen(&["serve", "--replay", path]);
    let (out_b, _, ok_b) = greengen(&["serve", "--replay", path]);
    assert!(ok_a && ok_b, "serve failed: {err_a}");
    assert_eq!(out_a, out_b, "replay must be byte-identical per seed");

    let lines: Vec<&str> = out_a.lines().collect();
    let mut epochs = 0usize;
    let mut plan_ids = Vec::new();
    let scenario = scenarios::scenario(1).unwrap();
    for line in &lines {
        let v = jsonio::parse(line).expect("every stdout line is JSON");
        match v.str_field("type").unwrap() {
            "epoch" => {
                epochs += 1;
                // schema: the stats consumers key on
                for field in [
                    "epoch",
                    "t",
                    "queued",
                    "constraints",
                    "placed",
                    "emissions_g",
                    "cost",
                    "dropped_samples",
                ] {
                    assert!(v.get(field).is_some(), "epoch line missing {field}: {line}");
                }
                assert_eq!(v.str_field("mode").unwrap(), "full");
                assert!(v.f64_field("placed").unwrap() > 0.0);
            }
            "plan" => {
                plan_ids.push(v.str_field("id").unwrap().to_string());
                let plan = DeploymentPlan::from_json(v.req("plan").unwrap()).unwrap();
                let problem = Problem {
                    app: &scenario.app,
                    infra: &scenario.infra,
                    constraints: &[],
                    objective: Objective::default(),
                };
                check_feasible(&problem, &plan).expect("served plan is feasible");
            }
            "summary" => {
                assert_eq!(line, lines.last().unwrap(), "summary is the final line");
                assert!(v.bool_field("shutdown").unwrap());
                assert_eq!(v.f64_field("skipped_malformed").unwrap(), 0.0);
            }
            other => panic!("unexpected line type {other}"),
        }
    }
    assert_eq!(epochs, 3);
    assert_eq!(plan_ids, ["r1", "r2"]);
}

#[test]
fn live_stdin_matches_replay_on_the_same_events() {
    let script = calm_script();
    let path = write_fixture("live-vs-replay.jsonl", &script);
    let (replay_out, _, ok) = greengen(&["serve", "--replay", path.to_str().unwrap()]);
    assert!(ok);

    let exe = env!("CARGO_BIN_EXE_greengen");
    let mut child = Command::new(exe)
        .arg("serve")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("daemon spawns");
    child
        .stdin
        .take()
        .unwrap()
        .write_all(script.as_bytes())
        .unwrap();
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let live_out = String::from_utf8_lossy(&out.stdout).to_string();
    assert_eq!(
        live_out, replay_out,
        "live stdin and --replay must emit identical responses"
    );
}

#[test]
fn faults_are_skipped_counted_and_never_fatal() {
    let script = [
        "this line is not json",
        r#"{"type":"quantum_flux","x":1}"#,
        r#"{"type":"metric_energy","t":3600,"service":"nosuchsvc","flavour":"tiny","joules":10}"#,
        r#"{"type":"metric_energy","t":3600,"service":"frontend","flavour":"large","joules":252000}"#,
        r#"{"type":"carbon","region":"ZZ","intensity":10}"#,
        r#"{"type":"node_down","node":"atlantis"}"#,
        r#"{"type":"tick","t":3600}"#,
        r#"{"type":"metric_energy","t":1800,"service":"frontend","flavour":"large","joules":100}"#,
        r#"{"type":"tick","t":1800}"#,
        // mid-stream EOF: no shutdown event
        "",
    ]
    .join("\n");
    let path = write_fixture("faults.jsonl", &script);
    let metrics = write_fixture("faults.prom", "");
    let (out, err, ok) = greengen(&[
        "serve",
        "--replay",
        path.to_str().unwrap(),
        "--metrics",
        metrics.to_str().unwrap(),
    ]);
    assert!(ok, "faults must not crash the daemon: {err}");

    let summary = jsonio::parse(out.lines().last().unwrap()).unwrap();
    assert_eq!(summary.str_field("type").unwrap(), "summary");
    assert_eq!(summary.f64_field("skipped_malformed").unwrap(), 1.0);
    assert_eq!(summary.f64_field("skipped_unknown_type").unwrap(), 1.0);
    // nosuchsvc + region ZZ + node atlantis
    assert_eq!(summary.f64_field("skipped_unknown_name").unwrap(), 3.0);
    // one stale sample + one stale tick
    assert_eq!(summary.f64_field("skipped_stale").unwrap(), 2.0);
    assert_eq!(summary.f64_field("epochs").unwrap(), 1.0);
    assert!(!summary.bool_field("shutdown").unwrap(), "ended on EOF");

    // the same accounting is visible in the exported metrics
    let prom = std::fs::read_to_string(&metrics).unwrap();
    assert!(
        prom.contains("greengen_sched_serve_skipped_total"),
        "skip counters exported: {prom}"
    );
    assert!(prom.contains("greengen_sched_serve_events_total"));
}

#[test]
fn burst_degrades_to_incremental_and_holds_the_deadline() {
    // 120 samples against a 64-deep ring: 56 drop, and the 64 pending
    // events at the tick are far above the high-water mark of 32
    let mut script = String::new();
    for i in 1..=120u32 {
        script.push_str(&format!(
            "{{\"type\":\"metric_energy\",\"t\":{},\"service\":\"frontend\",\"flavour\":\"large\",\"joules\":{}}}\n",
            60 * i,
            250_000 + i
        ));
    }
    script.push_str("{\"type\":\"tick\",\"t\":7200}\n{\"type\":\"shutdown\"}\n");
    let path = write_fixture("burst.jsonl", &script);
    let args = [
        "serve",
        "--replay",
        path.to_str().unwrap(),
        "--queue",
        "64",
        "--high-water",
        "32",
        "--deadline-ms",
        "400",
    ];
    let (out, err, ok) = greengen(&args);
    assert!(ok, "burst run failed: {err}");

    let epoch = jsonio::parse(out.lines().next().unwrap()).unwrap();
    assert_eq!(epoch.str_field("type").unwrap(), "epoch");
    assert_eq!(
        epoch.str_field("mode").unwrap(),
        "incremental",
        "above high-water the daemon must take the incremental path"
    );
    let summary = jsonio::parse(out.lines().last().unwrap()).unwrap();
    assert_eq!(summary.f64_field("dropped_samples").unwrap(), 56.0);
    assert_eq!(summary.f64_field("epochs_incremental").unwrap(), 1.0);
    assert_eq!(summary.f64_field("epochs_full").unwrap(), 0.0);

    // every epoch latency respects the deadline plus the stated tolerance
    let mut latency_lines = 0usize;
    for line in err.lines().filter(|l| l.starts_with("# serve epoch=")) {
        latency_lines += 1;
        let ms: f64 = line
            .split_whitespace()
            .find_map(|tok| tok.strip_prefix("latency_ms="))
            .expect("latency field")
            .parse()
            .unwrap();
        assert!(
            ms <= 400.0 + TOLERANCE_MS,
            "epoch latency {ms}ms exceeds deadline+tolerance: {line}"
        );
    }
    assert_eq!(latency_lines, 1);

    // control: the same flags on a calm stream stay on the full path
    let calm = concat!(
        "{\"type\":\"metric_energy\",\"t\":3600,\"service\":\"frontend\",\"flavour\":\"large\",\"joules\":252000}\n",
        "{\"type\":\"tick\",\"t\":3600}\n",
        "{\"type\":\"shutdown\"}\n"
    );
    let calm_path = write_fixture("burst-control.jsonl", calm);
    let (out, _, ok) = greengen(&[
        "serve",
        "--replay",
        calm_path.to_str().unwrap(),
        "--queue",
        "64",
        "--high-water",
        "32",
        "--deadline-ms",
        "400",
    ]);
    assert!(ok);
    let epoch = jsonio::parse(out.lines().next().unwrap()).unwrap();
    assert_eq!(epoch.str_field("mode").unwrap(), "full");
}
