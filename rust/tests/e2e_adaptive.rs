//! End-to-end integration: the adaptive loop (monitor → learn → schedule
//! → measure), the Prometheus interchange path, and the TimeShift
//! extension — the cross-cutting behaviours no single module test covers.

use greengen::config::scenarios;
use greengen::constraints::TimeShiftPlanner;
use greengen::energy::EnergyEstimator;
use greengen::monitoring::{prometheus, MetricStore, WorkloadSimulator};
use greengen::pipeline::{AdaptiveConfig, AdaptiveLoop, GeneratorPipeline, PipelineConfig};
use greengen::scheduler::Objective;

#[test]
fn adaptive_loop_reduces_emissions_on_every_scenario_infra() {
    for scenario_id in [1, 2] {
        let scenario = scenarios::scenario(scenario_id).unwrap();
        let mut looper = AdaptiveLoop::new(
            PipelineConfig::default(),
            AdaptiveConfig {
                hours: 24,
                regen_every: 6,
                failure_rate: 0.0,
                objective: Objective::default(),
                seed: 0xE2E + scenario_id as u64,
                incremental: false,
                zones: 0,
                horizon: 0,
                threads: 1,
            },
        );
        let summary = looper.run(&scenario).unwrap();
        // Reduction is bounded by what the infrastructure offers: the EU
        // grid (16..335) leaves a huge gap, the US grid (229..570) a small
        // one. The architecture-level claim is recovery of the achievable
        // gap, so assert on oracle recovery.
        assert!(
            summary.reduction_vs_cost_only() > 0.05,
            "scenario {scenario_id}: only {:.1}% reduction",
            summary.reduction_vs_cost_only() * 100.0
        );
        // On the near-flat US grid the few surviving constraints recover
        // just under half the (small) gap; on the EU grid > 80 %.
        assert!(
            summary.oracle_recovery() > 0.35,
            "scenario {scenario_id}: only {:.1}% of the oracle gap recovered",
            summary.oracle_recovery() * 100.0
        );
        // oracle sandwich: oracle <= constrained <= cost-only
        assert!(summary.total_oracle_g <= summary.total_constrained_g + 1e-6);
        assert!(summary.total_constrained_g <= summary.total_cost_only_g);
    }
}

#[test]
fn adaptive_loop_survives_heavy_failure_injection() {
    let scenario = scenarios::scenario(1).unwrap();
    let mut looper = AdaptiveLoop::new(
        PipelineConfig::default(),
        AdaptiveConfig {
            hours: 36,
            regen_every: 3,
            failure_rate: 1.0, // a node fails every single epoch
            objective: Objective::default(),
            seed: 0xFA11,
            incremental: false,
            zones: 0,
            horizon: 0,
            threads: 1,
        },
    );
    let summary = looper.run(&scenario).unwrap();
    assert_eq!(summary.epochs.len(), 12);
    // every epoch lost a node yet all plans were feasible and green
    assert!(summary.epochs.iter().all(|e| e.failed_node.is_some()));
    assert!(summary.reduction_vs_cost_only() > 0.3);
}

#[test]
fn monitoring_survives_prometheus_round_trip() {
    // Pipeline fed from metrics that went through the text exposition
    // format must produce identical constraints to the in-memory path.
    let scenario = scenarios::scenario(1).unwrap();
    let mut sim = WorkloadSimulator::new(scenario.truth.clone(), scenario.seed);
    let store = sim.run(0.0, scenario.windows);

    let text = prometheus::render(&store, 0.0, f64::INFINITY);
    let mut round_tripped = MetricStore::new();
    prometheus::ingest(&mut round_tripped, &text).unwrap();
    assert_eq!(round_tripped.energy_len(), store.energy_len());
    assert_eq!(round_tripped.traffic_len(), store.traffic_len());

    let run = |store: &MetricStore| {
        let mut pipeline = GeneratorPipeline::new(PipelineConfig::default());
        let mut app = scenario.app.clone();
        let mut infra = scenario.infra.clone();
        let t = store.horizon();
        let outcome = pipeline
            .run_epoch(&mut app, &mut infra, store, &scenario.intensity, t)
            .unwrap();
        outcome
            .ranked
            .iter()
            .map(|c| (c.kind.key(), (c.weight * 1e6).round()))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(&store), run(&round_tripped));
}

#[test]
fn timeshift_integrates_with_learned_profiles() {
    let scenario = scenarios::scenario(1).unwrap();
    let mut app = scenario.app.clone();
    let mut sim = WorkloadSimulator::new(scenario.truth.clone(), scenario.seed);
    let store = sim.run(0.0, scenario.windows);
    EnergyEstimator::default().estimate(&mut app, &store);

    let traces = GeneratorPipeline::trace_set(&scenario);
    let planner = TimeShiftPlanner::new(&traces);
    let regions: Vec<&str> = scenario.infra.nodes.iter().map(|n| n.region.as_str()).collect();
    let recs = planner.plan(&app, &regions, store.horizon()).unwrap();
    // the boutique preset marks email as batch-capable
    assert_eq!(recs.len(), 1);
    assert_eq!(recs[0].service, "email");
    // the recommended window is a real improvement over the worst choice
    assert!(recs[0].sav_hi > 0.0);
    assert!(recs[0].window_ci > 0.0);
    // France (CI 16 base) should host the greenest window in the EU set
    assert_eq!(recs[0].region, "FR");
}

#[test]
fn xla_and_native_pipelines_agree_through_the_adaptive_loop() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let scenario = scenarios::scenario(1).unwrap();
    let config = AdaptiveConfig {
        hours: 12,
        regen_every: 6,
        failure_rate: 0.0,
        objective: Objective::default(),
        seed: 0xAB,
        incremental: false,
        zones: 0,
        horizon: 0,
        threads: 1,
    };
    let mut native = AdaptiveLoop::new(PipelineConfig::default(), config);
    let mut accel = AdaptiveLoop::with_pipeline(
        GeneratorPipeline::with_xla(PipelineConfig::default(), "artifacts").unwrap(),
        config,
    );
    let a = native.run(&scenario).unwrap();
    let b = accel.run(&scenario).unwrap();
    assert_eq!(a.epochs.len(), b.epochs.len());
    for (x, y) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(x.constraints, y.constraints);
        assert!((x.constrained_g - y.constrained_g).abs() < 1e-3);
    }
}
