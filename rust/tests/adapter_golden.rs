//! Golden tests: the Constraint Adapter dialects must emit exactly the
//! documented syntax (schedulers parse these formats; drift breaks them).

use greengen::adapter::{adapter_for, JsonAdapter, MiniZincAdapter, PrologAdapter, SchedulerAdapter};
use greengen::constraints::{Constraint, ConstraintKind};
use greengen::jsonio;

fn fixture() -> Vec<Constraint> {
    let mut avoid = Constraint::new(
        ConstraintKind::AvoidNode {
            service: "frontend".into(),
            flavour: "large".into(),
            node: "italy".into(),
        },
        663.635,
        241.682,
        631.939,
    );
    avoid.weight = 1.0;
    let mut affinity = Constraint::new(
        ConstraintKind::Affinity {
            service: "frontend".into(),
            flavour: "large".into(),
            other: "productcatalog".into(),
        },
        123.456,
        123.456,
        123.456,
    );
    affinity.weight = 0.186;
    let mut prefer = Constraint::new(
        ConstraintKind::PreferNode {
            service: "currency".into(),
            flavour: "tiny".into(),
            node: "france".into(),
        },
        295.135,
        107.482,
        281.039,
    );
    prefer.weight = 0.445;
    vec![avoid, affinity, prefer]
}

#[test]
fn prolog_golden() {
    let got = PrologAdapter.format(&fixture());
    let want = "\
avoidNode(d(frontend, large), italy, 1.000).
affinity(d(frontend, large), d(productcatalog, _), 0.186).
preferNode(d(currency, tiny), france, 0.445).
";
    assert_eq!(got, want);
}

#[test]
fn json_golden_structure() {
    let text = JsonAdapter.format(&fixture());
    let v = jsonio::parse(&text).unwrap();
    let arr = v.as_array().unwrap();
    assert_eq!(arr.len(), 3);
    let kinds: Vec<&str> = arr
        .iter()
        .map(|c| c.req("kind").unwrap().str_field("type").unwrap())
        .collect();
    assert_eq!(kinds, vec!["AvoidNode", "Affinity", "PreferNode"]);
    // numeric fields preserved to full precision
    assert!((arr[0].f64_field("em").unwrap() - 663.635).abs() < 1e-9);
    assert!((arr[0].f64_field("savHi").unwrap() - 631.939).abs() < 1e-9);
    // round-trips through the constraint codec
    for c in arr {
        Constraint::from_json(c).unwrap();
    }
}

#[test]
fn minizinc_golden_lines() {
    let text = MiniZincAdapter.format(&fixture());
    assert!(text.contains(
        "var 0..1: viol_0 = bool2int(place[frontend] == italy /\\ flav[frontend] == large);"
    ));
    assert!(text.contains("float: w_0 = 1.0000;"));
    assert!(text.contains(
        "var 0..1: viol_1 = bool2int(place[frontend] != place[productcatalog] /\\ flav[frontend] == large);"
    ));
    assert!(text.contains(
        "var 0..1: viol_2 = bool2int(place[currency] != france /\\ flav[currency] == tiny);"
    ));
    assert!(text
        .contains("var float: green_penalty = w_0 * viol_0 + w_1 * viol_1 + w_2 * viol_2;"));
}

#[test]
fn adapter_registry_complete() {
    for name in ["prolog", "json", "minizinc"] {
        let adapter = adapter_for(name).unwrap();
        assert_eq!(adapter.name(), name);
        assert!(!adapter.format(&fixture()).is_empty());
    }
    assert!(adapter_for("yaml").is_none());
}

#[test]
fn empty_constraint_list_is_valid_output() {
    assert_eq!(PrologAdapter.format(&[]), "");
    let v = jsonio::parse(&JsonAdapter.format(&[])).unwrap();
    assert_eq!(v.as_array().unwrap().len(), 0);
}
