//! Property-based tests over the crate's cross-module invariants, run on
//! the in-tree deterministic harness (`util::proptest`).

use greengen::constraints::{ConstraintGenerator, ConstraintKind, GeneratorConfig};
use greengen::kb::ConstraintEntry;
use greengen::ranker::Ranker;
use greengen::runtime::{AnalyticsBackend, AnalyticsInput, NativeBackend};
use greengen::scheduler::problem::CapacityState;
use greengen::scheduler::{
    evaluate, CostOnlyScheduler, GreedyScheduler, Objective, Problem, Scheduler,
};
use greengen::simulate;
use greengen::util::proptest::check;
use greengen::util::Rng;

fn random_input(rng: &mut Rng) -> AnalyticsInput {
    let rows = 1 + rng.below(40);
    let nodes = 1 + rng.below(12);
    AnalyticsInput {
        e: (0..rows).map(|_| rng.range(0.0, 5.0) as f32).collect(),
        c: (0..nodes).map(|_| rng.range(0.0, 700.0) as f32).collect(),
        mask: (0..rows * nodes)
            .map(|_| if rng.chance(0.7) { 1.0 } else { 0.0 })
            .collect(),
        pool: (0..rng.below(20)).map(|_| rng.range(0.0, 300.0) as f32).collect(),
        alpha: rng.range(0.05, 1.0) as f32,
    }
}

#[test]
fn analytics_row_stats_are_order_statistics() {
    check("row stats ordering", 64, |rng| {
        let input = random_input(rng);
        let out = NativeBackend.run(&input).unwrap();
        for r in 0..input.rows() {
            assert!(out.row_min[r] <= out.row_max2[r] + 1e-6);
            assert!(out.row_max2[r] <= out.row_max[r] + 1e-6);
        }
    });
}

#[test]
fn analytics_savings_bounds_ordered_and_nonnegative() {
    check("savings bounds", 64, |rng| {
        let input = random_input(rng);
        let out = NativeBackend.run(&input).unwrap();
        for i in 0..out.sav_hi.len() {
            assert!(out.sav_lo[i] >= -1e-5, "sav_lo[{i}] = {}", out.sav_lo[i]);
            assert!(
                out.sav_lo[i] <= out.sav_hi[i] + 1e-4,
                "lo {} > hi {}",
                out.sav_lo[i],
                out.sav_hi[i]
            );
        }
    });
}

#[test]
fn tau_monotone_in_alpha() {
    check("tau monotone", 48, |rng| {
        let mut input = random_input(rng);
        input.alpha = rng.range(0.05, 0.85) as f32;
        let lo = NativeBackend.run(&input).unwrap().tau;
        input.alpha += 0.1;
        let hi = NativeBackend.run(&input).unwrap().tau;
        assert!(hi >= lo - 1e-6, "tau({}) = {hi} < tau(-0.1) = {lo}", input.alpha);
    });
}

#[test]
fn constraint_count_antimonotone_in_alpha() {
    check("count antimonotone", 16, |rng| {
        let services = 5 + rng.below(30);
        let nodes = 2 + rng.below(10);
        let app = simulate::random_application(rng, services);
        let infra = simulate::random_infrastructure(rng, nodes);
        let backend = NativeBackend;
        let count = |alpha: f64| {
            ConstraintGenerator::new(&backend)
                .with_config(GeneratorConfig {
                    alpha,
                    use_prolog: false,
                })
                .generate(&app, &infra)
                .unwrap()
                .constraints
                .len()
        };
        let strict = count(0.9);
        let loose = count(0.6);
        assert!(loose >= strict, "loose {loose} < strict {strict}");
    });
}

#[test]
fn generated_constraints_exceed_tau_and_respect_mask() {
    check("constraints above tau", 16, |rng| {
        let n_services = 10 + rng.below(20);
        let app = simulate::random_application(rng, n_services);
        let n_nodes = 2 + rng.below(8);
        let infra = simulate::random_infrastructure(rng, n_nodes);
        let backend = NativeBackend;
        let result = ConstraintGenerator::new(&backend)
            .with_config(GeneratorConfig {
                alpha: 0.8,
                use_prolog: false,
            })
            .generate(&app, &infra)
            .unwrap();
        for c in &result.constraints {
            assert!(c.em > result.tau);
            assert!(c.sav_lo <= c.sav_hi + 1e-6);
        }
    });
}

#[test]
fn ranker_invariants() {
    check("ranker weights", 64, |rng| {
        let n = 1 + rng.below(40);
        let entries: Vec<ConstraintEntry> = (0..n)
            .map(|i| ConstraintEntry {
                constraint: greengen::constraints::Constraint::new(
                    ConstraintKind::AvoidNode {
                        service: format!("s{i}"),
                        flavour: "f".into(),
                        node: format!("n{i}"),
                    },
                    rng.range(0.0, 1000.0),
                    0.0,
                    0.0,
                ),
                mu: rng.range(0.2, 1.0),
                generated_at: 0.0,
            })
            .collect();
        let ranked = Ranker::default().rank(&entries);
        // weights in (0, 1], sorted desc, max == 1 when non-empty
        for w in ranked.windows(2) {
            assert!(w[0].weight >= w[1].weight);
        }
        for c in &ranked {
            assert!(c.weight > 0.0 && c.weight <= 1.0 + 1e-12);
            assert!(c.weight >= 0.1); // discard threshold enforced
        }
        if let Some(top) = ranked.first() {
            assert!((top.weight - 1.0).abs() < 1e-9);
        }
    });
}

#[test]
fn scheduler_respects_hard_constraints() {
    check("scheduler hard feasibility", 24, |rng| {
        let n_services = 3 + rng.below(15);
        let app = simulate::random_application(rng, n_services);
        let n_nodes = 2 + rng.below(6);
        let infra = simulate::random_infrastructure(rng, n_nodes);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &[],
            objective: Objective::default(),
        };
        match GreedyScheduler::default().schedule(&problem) {
            Err(_) => {} // infeasible is allowed; silently skip
            Ok(plan) => {
                // mandatory services placed
                for s in &app.services {
                    if s.must_deploy {
                        assert!(plan.is_deployed(&s.id), "{} dropped", s.id);
                    }
                }
                // capacity respected (names resolved through the
                // interner: malformed placements are structured
                // UnknownId errors, not panicking position scans)
                let symbols = greengen::model::ModelIndex::new(&app, &infra);
                let mut cap = CapacityState::new(&infra);
                for p in &plan.placements {
                    let (sid, fid, nid) = symbols.resolve_placement(p).unwrap();
                    let (si, fi, ni) = (sid.index(), fid.index(), nid.index());
                    let req = &app.services[si].flavours[fi].requirements;
                    assert!(cap.fits(ni, req.cpu, req.ram_gb, req.storage_gb));
                    cap.take(ni, req.cpu, req.ram_gb, req.storage_gb);
                    // placement compatibility
                    assert!(infra.nodes[ni]
                        .placement_compatible(&app.services[si].requirements));
                }
            }
        }
    });
}

#[test]
fn constrained_scheduler_never_worse_than_cost_only_on_emissions() {
    // With constraints generated from ground truth, the constrained
    // greedy plan's emissions are <= the carbon-blind plan's in the
    // aggregate. Individual instances may tie.
    check("constraints reduce emissions", 12, |rng| {
        let n_services = 8 + rng.below(10);
        let app = simulate::random_application(rng, n_services);
        let n_nodes = 3 + rng.below(5);
        let infra = simulate::random_infrastructure(rng, n_nodes);
        let backend = NativeBackend;
        let generated = ConstraintGenerator::new(&backend)
            .with_config(GeneratorConfig {
                alpha: 0.7,
                use_prolog: false,
            })
            .generate(&app, &infra)
            .unwrap();
        let entries: Vec<ConstraintEntry> = generated
            .constraints
            .iter()
            .map(|c| ConstraintEntry {
                constraint: c.clone(),
                mu: 1.0,
                generated_at: 0.0,
            })
            .collect();
        let ranked = Ranker::default().rank(&entries);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &ranked,
            objective: Objective::default(),
        };
        let (Ok(constrained), Ok(blind)) = (
            GreedyScheduler::default().schedule(&problem),
            CostOnlyScheduler.schedule(&problem),
        ) else {
            return; // infeasible instance; skip
        };
        let em_constrained = evaluate(&problem, &constrained).unwrap().emissions_g;
        let em_blind = evaluate(&problem, &blind).unwrap().emissions_g;
        // allow 5% tolerance: soft constraints can be overridden by cost
        assert!(
            em_constrained <= em_blind * 1.05 + 1.0,
            "constrained {em_constrained} vs blind {em_blind}"
        );
    });
}

#[test]
fn prolog_and_direct_generation_agree() {
    check("prolog == direct", 10, |rng| {
        let n_services = 5 + rng.below(10);
        let app = simulate::random_application(rng, n_services);
        let n_nodes = 2 + rng.below(5);
        let infra = simulate::random_infrastructure(rng, n_nodes);
        let backend = NativeBackend;
        let run = |use_prolog: bool| {
            let mut cs = ConstraintGenerator::new(&backend)
                .with_config(GeneratorConfig {
                    alpha: 0.8,
                    use_prolog,
                })
                .generate(&app, &infra)
                .unwrap()
                .constraints;
            cs.sort_by(|a, b| a.kind.key().cmp(&b.kind.key()));
            cs
        };
        assert_eq!(run(true), run(false));
    });
}

#[test]
fn jsonio_round_trip_fuzz() {
    use greengen::jsonio::{parse, to_string, to_string_pretty, Value};
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Number((rng.range(-1e6, 1e6) * 1000.0).round() / 1000.0),
            3 => {
                let len = rng.below(12);
                Value::String(
                    (0..len)
                        .map(|_| {
                            let choices = ['a', 'é', '"', '\\', '\n', '😀', 'z', '\t'];
                            *rng.pick(&choices)
                        })
                        .collect(),
                )
            }
            4 => Value::Array((0..rng.below(5)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => Value::Object(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("jsonio round trip", 128, |rng| {
        let v = random_value(rng, 3);
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    });
}

#[test]
fn prolog_unification_laws() {
    use greengen::prolog::{parse_term, Term};
    check("unification symmetry", 64, |rng| {
        let atoms = ["a", "b", "frontend", "italy"];
        fn random_term(rng: &mut Rng, atoms: &[&str], depth: usize) -> Term {
            match if depth == 0 { rng.below(3) } else { rng.below(4) } {
                0 => Term::atom(*rng.pick(atoms)),
                1 => Term::Num((rng.range(0.0, 100.0) * 10.0).round() / 10.0),
                2 => Term::var(format!("V{}", rng.below(3))),
                _ => Term::compound(
                    "f",
                    (0..1 + rng.below(2))
                        .map(|_| random_term(rng, atoms, depth - 1))
                        .collect(),
                ),
            }
        }
        let a = random_term(rng, &atoms, 2);
        let b = random_term(rng, &atoms, 2);
        // symmetry of unification success
        let mut sub_ab = Default::default();
        let mut sub_ba = Default::default();
        let ab = unify(&a, &b, &mut sub_ab);
        let ba = unify(&b, &a, &mut sub_ba);
        assert_eq!(ab, ba, "{a} vs {b}");
        // reflexivity on ground terms
        if !format!("{a}").contains('V') {
            let mut s = Default::default();
            assert!(unify(&a, &a, &mut s));
        }
        // display/parse round trip on ground terms
        if !format!("{a}").contains('V') {
            let reparsed = parse_term(&a.to_string()).unwrap();
            assert_eq!(reparsed, a);
        }
    });
}

// Small shim: expose unification through the public engine (Subst is
// crate-private; use Database with dif/=-style query instead).
fn unify(a: &greengen::prolog::Term, b: &greengen::prolog::Term, _: &mut ()) -> bool {
    let mut db = greengen::prolog::Database::new();
    db.assert_fact(greengen::prolog::Term::compound("left", vec![a.clone()]))
        .unwrap();
    // query: left(b) succeeds iff a and b unify
    let goals = vec![greengen::prolog::Term::compound("left", vec![b.clone()])];
    !db.solve_goals(&goals).unwrap().is_empty()
}
