//! Integration: full pipeline vs the paper's §5.3 scenario listings and
//! §5.4 explainability figures.

use greengen::config::scenarios;
use greengen::constraints::ConstraintKind;
use greengen::pipeline::{EpochOutcome, GeneratorPipeline, PipelineConfig};

fn run(n: usize) -> EpochOutcome {
    let mut pipeline = GeneratorPipeline::new(PipelineConfig::default());
    pipeline
        .run_scenario(&scenarios::scenario(n).unwrap())
        .unwrap()
}

fn avoid_weight(outcome: &EpochOutcome, svc: &str, fl: &str, node: &str) -> Option<f64> {
    outcome.ranked.iter().find_map(|c| match &c.kind {
        ConstraintKind::AvoidNode {
            service,
            flavour,
            node: nd,
        } if service == svc && flavour == fl && nd == node => Some(c.weight),
        _ => None,
    })
}

#[test]
fn scenario1_paper_listing() {
    let outcome = run(1);
    // paper: avoidNode(d(frontend,large), italy, 1.0)
    assert!((avoid_weight(&outcome, "frontend", "large", "italy").unwrap() - 1.0).abs() < 1e-9);
    // paper: avoidNode(d(frontend,large), greatbritain, 0.636)
    assert!(
        (avoid_weight(&outcome, "frontend", "large", "greatbritain").unwrap() - 0.636).abs()
            < 0.02
    );
    // paper: avoidNode(d(productcatalog,large), italy, _) present
    assert!(avoid_weight(&outcome, "productcatalog", "large", "italy").is_some());
    // France (16 g/kWh) must never be avoided at baseline
    assert!(outcome.ranked.iter().all(|c| !matches!(&c.kind,
        ConstraintKind::AvoidNode { node, .. } if node == "france")));
}

#[test]
fn scenario2_paper_listing() {
    let outcome = run(2);
    assert!((avoid_weight(&outcome, "frontend", "large", "florida").unwrap() - 1.0).abs() < 1e-9);
    for (node, w) in [("washington", 0.428), ("california", 0.412), ("newyork", 0.414)] {
        let got = avoid_weight(&outcome, "frontend", "large", node).unwrap();
        assert!((got - w).abs() < 0.02, "{node}: {got} vs paper {w}");
    }
    assert!(avoid_weight(&outcome, "productcatalog", "large", "florida").is_some());
}

#[test]
fn scenario3_france_prioritised() {
    let outcome = run(3);
    let fr = avoid_weight(&outcome, "frontend", "large", "france").expect("france avoided");
    let gb = avoid_weight(&outcome, "frontend", "large", "greatbritain").unwrap_or(0.0);
    assert!(fr > gb, "france {fr} should outweigh gb {gb} at CI 376 vs 213");
    // france (376) is now the dirtiest node: it takes the top weight,
    // and italy (335) drops to ≈ 335/376 = 0.891
    assert!((fr - 1.0).abs() < 1e-9, "{fr}");
    let it = avoid_weight(&outcome, "frontend", "large", "italy").unwrap();
    assert!((it - 335.0 / 376.0).abs() < 0.02, "{it}");
}

#[test]
fn scenario4_paper_listing() {
    let outcome = run(4);
    assert!(
        (avoid_weight(&outcome, "productcatalog", "large", "italy").unwrap() - 1.0).abs() < 1e-9
    );
    // paper: avoidNode(d(currency,tiny), italy, 0.89)
    let currency = avoid_weight(&outcome, "currency", "tiny", "italy").unwrap();
    assert!((currency - 0.89).abs() < 0.02, "{currency}");
}

#[test]
fn scenario5_affinity_emerges_with_volume() {
    let baseline = run(1);
    let surged = run(5);
    let count = |o: &EpochOutcome| {
        o.ranked
            .iter()
            .filter(|c| matches!(c.kind, ConstraintKind::Affinity { .. }))
            .count()
    };
    assert_eq!(count(&baseline), 0, "no affinities at baseline traffic");
    assert!(count(&surged) > 0, "affinities must survive x15000 traffic");
}

#[test]
fn explainability_savings_match_section_5_4() {
    // §5.4 reports (computed from Table 1/2): frontend-large on GB saves
    // [160.51, 390.38], on Italy [241.76, 632.14]. Our simulated profiles
    // land within 2% of the analytic values.
    let outcome = run(1);
    let find = |node: &str| {
        outcome
            .ranked
            .iter()
            .find(|c| {
                matches!(&c.kind, ConstraintKind::AvoidNode { service, flavour, node: n }
                if service == "frontend" && flavour == "large" && n == node)
            })
            .unwrap()
    };
    let gb = find("greatbritain");
    assert!((gb.sav_hi - 390.3).abs() / 390.3 < 0.02, "{}", gb.sav_hi);
    assert!((gb.sav_lo - 160.5).abs() / 160.5 < 0.02, "{}", gb.sav_lo);
    let it = find("italy");
    assert!((it.sav_hi - 631.9).abs() / 631.9 < 0.02, "{}", it.sav_hi);
    assert!((it.sav_lo - 241.7).abs() / 241.7 < 0.02, "{}", it.sav_lo);

    // and the report text carries them
    let entry = outcome
        .report
        .entries
        .iter()
        .find(|e| e.constraint == *it)
        .unwrap();
    assert!(entry.rationale.contains("estimated emissions savings"));
}

#[test]
fn xla_backend_reproduces_scenario1_if_artifacts_present() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut native = GeneratorPipeline::new(PipelineConfig::default());
    let mut xla = GeneratorPipeline::with_xla(PipelineConfig::default(), "artifacts").unwrap();
    let scenario = scenarios::scenario(1).unwrap();
    let a = native.run_scenario(&scenario).unwrap();
    let b = xla.run_scenario(&scenario).unwrap();
    assert_eq!(a.ranked.len(), b.ranked.len());
    for (x, y) in a.ranked.iter().zip(&b.ranked) {
        assert_eq!(x.kind, y.kind);
        assert!((x.weight - y.weight).abs() < 1e-5);
    }
}
