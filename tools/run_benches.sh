#!/usr/bin/env bash
# Run every bench target and rewrite the committed BENCH_*.json
# baselines (continuum, forecast, generation, solver, scalability).
#
# The authoring containers for PRs 1-5 had no Rust toolchain, so those
# files were committed as honest null-valued schema placeholders. Run
# this script from the first machine that has cargo, then commit the
# rewritten BENCH_*.json files:
#
#   bash tools/run_benches.sh
#   git add BENCH_*.json && git commit -m "Record measured bench baselines"
#
# The remaining bench targets write CSVs under results/ (not committed)
# or need optional PJRT artifacts; failures there are reported but do
# not abort the JSON baselines.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: no cargo on PATH — run this from a machine with a Rust toolchain" >&2
    exit 1
fi

# Targets that rewrite a committed BENCH_*.json baseline.
json_benches=(continuum forecast generation solver scalability)
for b in "${json_benches[@]}"; do
    echo "== cargo bench --bench $b"
    cargo bench --bench "$b"
done

# CSV-only / optional targets (runtime_xla needs PJRT artifacts).
extra_benches=(ablations scenarios scheduler threshold runtime_xla)
for b in "${extra_benches[@]}"; do
    echo "== cargo bench --bench $b (optional)"
    cargo bench --bench "$b" || echo "warn: bench '$b' failed (optional target)" >&2
done

echo
echo "Rewritten baselines:"
git status --short -- 'BENCH_*.json' || true
