#!/usr/bin/env bash
# Throughput regression gate over the committed BENCH_*.json baselines.
#
# For each JSON bench target this script snapshots the committed
# baseline, re-runs the bench (which overwrites the file), restores the
# baseline, and then compares every `*_per_s` throughput series between
# the two — positionally, since the bench emits rows in a fixed order.
# A fresh value more than THRESHOLD percent below its baseline
# counterpart fails the script.
#
# Baselines still holding their honest null placeholders (the authoring
# containers have no Rust toolchain — see tools/run_benches.sh) are
# skipped: there is nothing real to regress against yet, so until the
# first machine with cargo commits measured numbers this gate is
# advisory by construction. CI runs it with continue-on-error for the
# same reason.
#
#   bash tools/bench_diff.sh              # default 20% threshold
#   BENCH_DIFF_THRESHOLD=10 bash tools/bench_diff.sh
set -euo pipefail
cd "$(dirname "$0")/.."

THRESHOLD="${BENCH_DIFF_THRESHOLD:-20}"

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench_diff: no cargo on PATH — nothing to diff" >&2
    exit 0
fi

# Extract '"<key>_per_s": <number>' pairs, one per line, in file order.
throughputs() {
    grep -oE '"[a-z0-9_]+_per_s"[[:space:]]*:[[:space:]]*[0-9][0-9.eE+-]*' "$1" \
        | tr -d ' ' || true
}

fail=0
for b in continuum forecast generation solver scalability; do
    baseline="BENCH_${b}.json"
    if [[ ! -f "$baseline" ]]; then
        echo "bench_diff: $baseline missing — skipped"
        continue
    fi
    if grep -q 'baseline-pending' "$baseline" || ! throughputs "$baseline" | grep -q .; then
        echo "bench_diff: $baseline has no measured throughput yet — skipped (advisory)"
        continue
    fi

    snapshot="$(mktemp)"
    cp "$baseline" "$snapshot"
    echo "== cargo bench --bench $b"
    if ! cargo bench --bench "$b"; then
        cp "$snapshot" "$baseline"
        rm -f "$snapshot"
        echo "bench_diff: bench '$b' failed to run" >&2
        fail=1
        continue
    fi
    fresh="$(mktemp)"
    cp "$baseline" "$fresh"
    cp "$snapshot" "$baseline" # keep the committed baseline untouched

    # Positional compare: same bench, same row order, same keys.
    if ! paste -d' ' <(throughputs "$snapshot") <(throughputs "$fresh") \
        | awk -v thr="$THRESHOLD" -F'[: ]' '
            NF >= 4 && $2 + 0 > 0 {
                drop = (1 - $4 / $2) * 100
                if (drop > thr) {
                    printf "REGRESSION %s: %.1f -> %.1f (-%.1f%% > %s%%)\n", \
                        $1, $2, $4, drop, thr
                    bad = 1
                }
            }
            END { exit bad }
        '; then
        echo "bench_diff: throughput regression in bench '$b' (baseline $baseline)" >&2
        fail=1
    else
        echo "bench_diff: $b within ${THRESHOLD}% of baseline"
    fi
    rm -f "$snapshot" "$fresh"
done

exit "$fail"
