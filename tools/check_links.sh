#!/usr/bin/env bash
# Docs link checker: fail on dead *relative* markdown links in README.md
# and docs/. External (http/https/mailto) links and pure #anchors are
# skipped — the build environment is offline. Anchors on relative links
# are checked for file existence only.
#
# Usage: tools/check_links.sh [repo-root]
set -u

root="${1:-.}"
fail=0

check_file() {
    local file="$1"
    local dir
    dir="$(dirname "$file")"
    # pull every ](target) occurrence out of inline markdown links
    # (grep -o keeps it line-based; multi-line link targets don't occur
    # in this tree and would be a style bug anyway)
    while IFS= read -r target; do
        case "$target" in
            http://*|https://*|mailto:*|\#*) continue ;;
        esac
        local path="${target%%#*}"
        [ -z "$path" ] && continue
        # resolve ONLY against the containing file's directory — that is
        # how rendered markdown resolves it; a repo-root fallback would
        # green-light links that 404 when rendered
        if [ ! -e "$dir/$path" ]; then
            echo "DEAD LINK: $file -> $target"
            fail=1
        fi
    done < <(grep -o ']([^)]*)' "$file" | sed 's/^](//; s/)$//')
}

for f in "$root"/README.md "$root"/docs/*.md; do
    [ -e "$f" ] || continue
    check_file "$f"
done

if [ "$fail" -ne 0 ]; then
    echo "docs link check FAILED"
    exit 1
fi
echo "docs link check OK"
