//! Bench E9/E10 (Table 4, Fig. 3): constraint generation across quantile
//! thresholds on the 100×100 randomized instance.

use greengen::benchkit::{Bench, BenchConfig};
use greengen::constraints::{ConstraintGenerator, GeneratorConfig};
use greengen::runtime::NativeBackend;
use greengen::simulate;
use greengen::util::Rng;
use std::time::Duration;

fn main() {
    let mut bench = Bench::new(BenchConfig {
        warmup_iters: 1,
        min_iters: 5,
        max_iters: 100,
        min_time: Duration::from_millis(400),
    });
    let mut rng = Rng::new(0x7A81e4);
    let app = simulate::random_application(&mut rng, 100);
    let infra = simulate::random_infrastructure(&mut rng, 100);
    let backend = NativeBackend;

    for level in [0.9, 0.8, 0.7, 0.6, 0.5] {
        bench.bench(&format!("table4/quantile-{level}"), || {
            ConstraintGenerator::new(&backend)
                .with_config(GeneratorConfig {
                    alpha: level,
                    use_prolog: false,
                })
                .generate(&app, &infra)
                .unwrap()
                .constraints
                .len()
        });
    }
    std::fs::create_dir_all("results").ok();
    bench
        .write_csv(std::path::Path::new("results/bench_threshold.csv"))
        .ok();
}
