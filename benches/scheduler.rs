//! Bench: scheduler solve time — greedy vs exact (small instances),
//! greedy scaling (large instances), baselines.

use greengen::benchkit::{Bench, BenchConfig};
use greengen::constraints::{ConstraintGenerator, GeneratorConfig};
use greengen::runtime::NativeBackend;
use greengen::scheduler::{
    BranchAndBoundScheduler, CostOnlyScheduler, GreedyScheduler, Objective, Problem, Scheduler,
};
use greengen::simulate;
use greengen::util::Rng;
use std::time::Duration;

fn main() {
    let mut bench = Bench::new(BenchConfig {
        warmup_iters: 1,
        min_iters: 5,
        max_iters: 100,
        min_time: Duration::from_millis(400),
    });
    let backend = NativeBackend;

    // small instance: exact vs greedy
    let mut rng = Rng::new(0x5C);
    let small_app = simulate::random_application(&mut rng, 6);
    let small_infra = simulate::random_infrastructure(&mut rng, 4);
    let result = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.8,
            use_prolog: false,
        })
        .generate(&small_app, &small_infra)
        .unwrap();
    let problem = Problem {
        app: &small_app,
        infra: &small_infra,
        constraints: &result.constraints,
        objective: Objective::default(),
    };
    bench.bench("small-6x4/greedy", || {
        GreedyScheduler::default().schedule(&problem).map(|p| p.placements.len())
    });
    bench.bench("small-6x4/exact-bnb", || {
        BranchAndBoundScheduler::default()
            .schedule(&problem)
            .map(|p| p.placements.len())
    });
    bench.bench("small-6x4/cost-only", || {
        CostOnlyScheduler.schedule(&problem).map(|p| p.placements.len())
    });

    // greedy scaling
    for (services, nodes) in [(20usize, 10usize), (50, 20), (100, 50), (200, 50)] {
        let mut rng = Rng::new((services + nodes) as u64);
        let app = simulate::random_application(&mut rng, services);
        let infra = simulate::random_infrastructure(&mut rng, nodes);
        let result = ConstraintGenerator::new(&backend)
            .with_config(GeneratorConfig {
                alpha: 0.8,
                use_prolog: false,
            })
            .generate(&app, &infra)
            .unwrap();
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &result.constraints,
            objective: Objective::default(),
        };
        bench.bench(&format!("greedy/{services}x{nodes}"), || {
            GreedyScheduler::default().schedule(&problem).map(|p| p.placements.len())
        });
    }
    std::fs::create_dir_all("results").ok();
    bench
        .write_csv(std::path::Path::new("results/bench_scheduler.csv"))
        .ok();
}
