//! Bench: full vs incremental constraint generation across adaptive
//! epochs with sparse changes — the O(|services|·|nodes|) → O(changed)
//! claim, measured.
//!
//! Each case generates a continuum topology, runs one cold epoch, then
//! `EPOCHS` warm epochs that perturb `changed` random energy profiles
//! before regenerating through (a) the classic full
//! `ConstraintGenerator::generate` pass and (b) the carried
//! `IncrementalGenerator`. Outputs are asserted identical (τ bit-equal,
//! same constraint multiset size) so the timings compare equal work.
//!
//! Writes `BENCH_generation.json` into the working directory so the
//! numbers can be committed as the perf-trajectory baseline.

use greengen::constraints::{
    ConstraintGenerator, ConstraintLibrary, GeneratorConfig, IncrementalGenerator,
};
use greengen::energy::estimator::EstimationReport;
use greengen::energy::EnergyEstimator;
use greengen::jsonio::Value;
use greengen::model::Application;
use greengen::monitoring::{EnergySample, MetricStore, TrafficSample};
use greengen::runtime::NativeBackend;
use greengen::simulate::{topology, Topology, TopologySpec};
use greengen::util::Rng;
use std::time::Instant;

const EPOCHS: usize = 5;

fn perturb_profiles(rng: &mut Rng, app: &mut Application, changed: usize) {
    for _ in 0..changed {
        let si = rng.below(app.services.len());
        let svc = &mut app.services[si];
        let fi = rng.below(svc.flavours.len());
        if let Some(profile) = &mut svc.flavours[fi].energy {
            profile.kwh *= rng.range(0.85, 1.18);
        }
    }
}

fn case(
    topo: Topology,
    nodes: usize,
    services: usize,
    changed: usize,
    use_prolog: bool,
) -> Value {
    let spec = TopologySpec::new(topo, nodes, services)
        .with_zones(8)
        .with_seed(0x9E4E);
    let (mut app, infra) = topology::generate(&spec);
    let backend = NativeBackend;
    let config = GeneratorConfig {
        alpha: 0.8,
        use_prolog,
    };
    let library = ConstraintLibrary::default();
    let mut inc = IncrementalGenerator::new(config);
    // cold pass: seed the carry state (not timed — both sides amortise it)
    let (cold, _) = inc
        .generate(&backend, &library, &app, &infra)
        .expect("cold generation");
    let rows = cold.rows.len();

    let mut rng = Rng::new(0xBE_9C ^ changed as u64);
    let mut full_s = 0.0f64;
    let mut inc_s = 0.0f64;
    let mut dirty_total = 0usize;
    for _ in 0..EPOCHS {
        perturb_profiles(&mut rng, &mut app, changed);

        let t0 = Instant::now();
        let full = ConstraintGenerator::new(&backend)
            .with_config(config)
            .generate(&app, &infra)
            .expect("full generation");
        full_s += t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let (result, stats) = inc
            .generate(&backend, &library, &app, &infra)
            .expect("incremental generation");
        inc_s += t0.elapsed().as_secs_f64();
        dirty_total += stats.dirty_rows;

        assert_eq!(full.tau.to_bits(), result.tau.to_bits(), "tau diverged");
        assert_eq!(
            full.constraints.len(),
            result.constraints.len(),
            "constraint count diverged"
        );
    }
    let full_ms = full_s / EPOCHS as f64 * 1e3;
    let inc_ms = inc_s / EPOCHS as f64 * 1e3;
    let speedup = full_ms / inc_ms.max(1e-9);
    let mean_dirty = dirty_total as f64 / EPOCHS as f64;
    let mode = if use_prolog { "prolog" } else { "direct" };
    println!(
        "{:<22} {:>5}n x {:>5}s ({:>5} rows, {mode:>6})  ~{:>5} changed/epoch  \
         full {:>9.2} ms  incremental {:>9.2} ms  speedup x{:>6.2}  dirty rows {:>8.1}",
        topo.name(),
        nodes,
        services,
        rows,
        changed,
        full_ms,
        inc_ms,
        speedup,
        mean_dirty
    );
    Value::object(vec![
        ("topology", Value::from(topo.name())),
        ("mode", Value::from(mode)),
        ("nodes", Value::from(nodes as f64)),
        ("services", Value::from(services as f64)),
        ("rows", Value::from(rows as f64)),
        ("changed_profiles_per_epoch", Value::from(changed as f64)),
        ("full_ms", Value::from(full_ms)),
        ("incremental_ms", Value::from(inc_ms)),
        ("speedup", Value::from(speedup)),
        ("mean_dirty_rows", Value::from(mean_dirty)),
    ])
}

/// Monitoring ingest + summarisation throughput on the interned columnar
/// store: append `samples` observations across `series` hot series, run
/// one full estimator scan, then stream one small append batch through
/// the incremental estimator (the steady-state serve-loop shape).
fn ingest_case(samples: usize, series: usize) -> Value {
    let mut rng = Rng::new(0x16E5);
    let mut store = MetricStore::new();
    let mut app = Application::new("bench");

    let t0 = Instant::now();
    for i in 0..samples {
        let t = i as f64 * 0.25;
        let k = i % series;
        if i % 3 == 0 {
            store.push_traffic(TrafficSample {
                t,
                from: format!("s{k}"),
                from_flavour: "f0".to_string(),
                to: format!("s{}", (k + 1) % series),
                requests: 10.0,
                bytes: rng.range(1e3, 2e9),
            });
        } else {
            store.push_energy(EnergySample {
                t,
                service: format!("s{k}"),
                flavour: "f0".to_string(),
                joules: rng.range(1.0, 7.2e5),
            });
        }
    }
    let ingest_s = t0.elapsed().as_secs_f64();

    let estimator = EnergyEstimator::default();
    let t0 = Instant::now();
    let full: EstimationReport = estimator.estimate(&mut app, &store);
    let scan_s = t0.elapsed().as_secs_f64();
    let since = store.revision();

    // steady state: a 1% append batch, then the streaming refresh
    let batch = (samples / 100).max(1);
    let horizon = store.horizon();
    for i in 0..batch {
        store.push_energy(EnergySample {
            t: horizon + 1.0 + i as f64,
            service: format!("s{}", i % series),
            flavour: "f0".to_string(),
            joules: rng.range(1.0, 7.2e5),
        });
    }
    let t0 = Instant::now();
    let _inc = estimator.estimate_incremental(&mut app, &store, &full, since);
    let stream_s = t0.elapsed().as_secs_f64();

    let ingest_per_s = samples as f64 / ingest_s.max(1e-9);
    let scan_per_s = samples as f64 / scan_s.max(1e-9);
    let stream_per_s = batch as f64 / stream_s.max(1e-9);
    println!(
        "ingest {samples:>8} samples / {series:>4} series  \
         push {ingest_per_s:>12.0}/s  full-scan {scan_per_s:>12.0}/s  \
         stream {stream_per_s:>12.0}/s ({batch} appended)",
    );
    Value::object(vec![
        ("samples", Value::from(samples as f64)),
        ("series", Value::from(series as f64)),
        ("ingest_samples_per_s", Value::from(ingest_per_s)),
        ("full_scan_samples_per_s", Value::from(scan_per_s)),
        ("stream_samples_per_s", Value::from(stream_per_s)),
    ])
}

/// Full-generation throughput at a fixed instance size as the worker
/// thread count grows — the chunk-parallel library + analytics path.
/// Outputs are asserted bit-identical to the single-thread run, so every
/// row times exactly the same work.
fn thread_case(threads: usize, baseline_ms: Option<f64>) -> Value {
    let spec = TopologySpec::new(Topology::GeoRegions, 500, 1000)
        .with_zones(8)
        .with_seed(0x9E4E);
    let (app, infra) = topology::generate(&spec);
    let backend = NativeBackend;
    let config = GeneratorConfig {
        alpha: 0.8,
        use_prolog: false,
    };
    let reference = ConstraintGenerator::new(&backend)
        .with_config(config)
        .generate(&app, &infra)
        .expect("reference generation");

    let generator = ConstraintGenerator::new(&backend)
        .with_config(config)
        .with_threads(threads);
    let mut total_s = 0.0f64;
    let mut rows = 0usize;
    for _ in 0..EPOCHS {
        let t0 = Instant::now();
        let result = generator.generate(&app, &infra).expect("threaded generation");
        total_s += t0.elapsed().as_secs_f64();
        rows = result.rows.len();
        assert_eq!(
            reference.tau.to_bits(),
            result.tau.to_bits(),
            "tau diverged at {threads} threads"
        );
        assert_eq!(
            reference.constraints, result.constraints,
            "constraints diverged at {threads} threads"
        );
    }
    let gen_ms = total_s / EPOCHS as f64 * 1e3;
    let generations_per_s = 1e3 / gen_ms.max(1e-9);
    let rows_per_s = rows as f64 * EPOCHS as f64 / total_s.max(1e-9);
    let speedup = baseline_ms.map_or(1.0, |b| b / gen_ms.max(1e-9));
    println!(
        "threads {threads:>2}  full {gen_ms:>9.2} ms  \
         {generations_per_s:>7.2} gen/s  {rows_per_s:>12.0} rows/s  speedup x{speedup:>5.2}",
    );
    Value::object(vec![
        ("threads", Value::from(threads as f64)),
        ("full_ms", Value::from(gen_ms)),
        ("generations_per_s", Value::from(generations_per_s)),
        ("rows_per_s", Value::from(rows_per_s)),
        ("speedup_vs_1_thread", Value::from(speedup)),
    ])
}

fn main() {
    println!("# generation bench: full vs incremental epochs (mean of {EPOCHS})");
    let mut cases = Vec::new();
    // the numeric fast path at fleet scale: sparse vs broad change
    cases.push(case(Topology::GeoRegions, 500, 1000, 1, false));
    cases.push(case(Topology::GeoRegions, 500, 1000, 16, false));
    cases.push(case(Topology::GeoRegions, 500, 1000, 250, false));
    cases.push(case(Topology::CloudEdgeHierarchy, 600, 900, 16, false));
    cases.push(case(Topology::IotSwarm, 500, 600, 16, false));
    cases.push(case(Topology::HybridBurst, 500, 800, 16, false));
    // the paper-formulation Prolog path: the rule engine dominates, so
    // skipping clean rows pays off hardest here
    cases.push(case(Topology::GeoRegions, 40, 80, 1, true));
    cases.push(case(Topology::GeoRegions, 40, 80, 8, true));

    println!("\n# monitoring ingest -> estimator throughput (interned columnar store)");
    let ingest = vec![
        ingest_case(100_000, 64),
        ingest_case(1_000_000, 512),
    ];

    println!("\n# full-generation throughput per worker-thread count (mean of {EPOCHS})");
    let mut threads = Vec::new();
    let mut baseline_ms = None;
    for t in [1usize, 2, 4, 8] {
        let row = thread_case(t, baseline_ms);
        if t == 1 {
            baseline_ms = row.get("full_ms").and_then(|v| v.as_f64());
        }
        threads.push(row);
    }

    let out = Value::object(vec![
        ("bench", Value::from("generation")),
        ("status", Value::from("measured")),
        ("results", Value::array(cases)),
        ("ingest", Value::array(ingest)),
        ("threads", Value::array(threads)),
    ]);
    let path = std::path::Path::new("BENCH_generation.json");
    greengen::jsonio::to_file(path, &out).expect("write BENCH_generation.json");
    println!("wrote {}", path.display());
}
