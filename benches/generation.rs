//! Bench: full vs incremental constraint generation across adaptive
//! epochs with sparse changes — the O(|services|·|nodes|) → O(changed)
//! claim, measured.
//!
//! Each case generates a continuum topology, runs one cold epoch, then
//! `EPOCHS` warm epochs that perturb `changed` random energy profiles
//! before regenerating through (a) the classic full
//! `ConstraintGenerator::generate` pass and (b) the carried
//! `IncrementalGenerator`. Outputs are asserted identical (τ bit-equal,
//! same constraint multiset size) so the timings compare equal work.
//!
//! Writes `BENCH_generation.json` into the working directory so the
//! numbers can be committed as the perf-trajectory baseline.

use greengen::constraints::{
    ConstraintGenerator, ConstraintLibrary, GeneratorConfig, IncrementalGenerator,
};
use greengen::jsonio::Value;
use greengen::model::Application;
use greengen::runtime::NativeBackend;
use greengen::simulate::{topology, Topology, TopologySpec};
use greengen::util::Rng;
use std::time::Instant;

const EPOCHS: usize = 5;

fn perturb_profiles(rng: &mut Rng, app: &mut Application, changed: usize) {
    for _ in 0..changed {
        let si = rng.below(app.services.len());
        let svc = &mut app.services[si];
        let fi = rng.below(svc.flavours.len());
        if let Some(profile) = &mut svc.flavours[fi].energy {
            profile.kwh *= rng.range(0.85, 1.18);
        }
    }
}

fn case(
    topo: Topology,
    nodes: usize,
    services: usize,
    changed: usize,
    use_prolog: bool,
) -> Value {
    let spec = TopologySpec::new(topo, nodes, services)
        .with_zones(8)
        .with_seed(0x9E4E);
    let (mut app, infra) = topology::generate(&spec);
    let backend = NativeBackend;
    let config = GeneratorConfig {
        alpha: 0.8,
        use_prolog,
    };
    let library = ConstraintLibrary::default();
    let mut inc = IncrementalGenerator::new(config);
    // cold pass: seed the carry state (not timed — both sides amortise it)
    let (cold, _) = inc
        .generate(&backend, &library, &app, &infra)
        .expect("cold generation");
    let rows = cold.rows.len();

    let mut rng = Rng::new(0xBE_9C ^ changed as u64);
    let mut full_s = 0.0f64;
    let mut inc_s = 0.0f64;
    let mut dirty_total = 0usize;
    for _ in 0..EPOCHS {
        perturb_profiles(&mut rng, &mut app, changed);

        let t0 = Instant::now();
        let full = ConstraintGenerator::new(&backend)
            .with_config(config)
            .generate(&app, &infra)
            .expect("full generation");
        full_s += t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let (result, stats) = inc
            .generate(&backend, &library, &app, &infra)
            .expect("incremental generation");
        inc_s += t0.elapsed().as_secs_f64();
        dirty_total += stats.dirty_rows;

        assert_eq!(full.tau.to_bits(), result.tau.to_bits(), "tau diverged");
        assert_eq!(
            full.constraints.len(),
            result.constraints.len(),
            "constraint count diverged"
        );
    }
    let full_ms = full_s / EPOCHS as f64 * 1e3;
    let inc_ms = inc_s / EPOCHS as f64 * 1e3;
    let speedup = full_ms / inc_ms.max(1e-9);
    let mean_dirty = dirty_total as f64 / EPOCHS as f64;
    let mode = if use_prolog { "prolog" } else { "direct" };
    println!(
        "{:<22} {:>5}n x {:>5}s ({:>5} rows, {mode:>6})  ~{:>5} changed/epoch  \
         full {:>9.2} ms  incremental {:>9.2} ms  speedup x{:>6.2}  dirty rows {:>8.1}",
        topo.name(),
        nodes,
        services,
        rows,
        changed,
        full_ms,
        inc_ms,
        speedup,
        mean_dirty
    );
    Value::object(vec![
        ("topology", Value::from(topo.name())),
        ("mode", Value::from(mode)),
        ("nodes", Value::from(nodes as f64)),
        ("services", Value::from(services as f64)),
        ("rows", Value::from(rows as f64)),
        ("changed_profiles_per_epoch", Value::from(changed as f64)),
        ("full_ms", Value::from(full_ms)),
        ("incremental_ms", Value::from(inc_ms)),
        ("speedup", Value::from(speedup)),
        ("mean_dirty_rows", Value::from(mean_dirty)),
    ])
}

fn main() {
    println!("# generation bench: full vs incremental epochs (mean of {EPOCHS})");
    let mut cases = Vec::new();
    // the numeric fast path at fleet scale: sparse vs broad change
    cases.push(case(Topology::GeoRegions, 500, 1000, 1, false));
    cases.push(case(Topology::GeoRegions, 500, 1000, 16, false));
    cases.push(case(Topology::GeoRegions, 500, 1000, 250, false));
    cases.push(case(Topology::CloudEdgeHierarchy, 600, 900, 16, false));
    cases.push(case(Topology::IotSwarm, 500, 600, 16, false));
    cases.push(case(Topology::HybridBurst, 500, 800, 16, false));
    // the paper-formulation Prolog path: the rule engine dominates, so
    // skipping clean rows pays off hardest here
    cases.push(case(Topology::GeoRegions, 40, 80, 1, true));
    cases.push(case(Topology::GeoRegions, 40, 80, 8, true));

    let out = Value::object(vec![
        ("bench", Value::from("generation")),
        ("status", Value::from("measured")),
        ("results", Value::array(cases)),
    ]);
    let path = std::path::Path::new("BENCH_generation.json");
    greengen::jsonio::to_file(path, &out).expect("write BENCH_generation.json");
    println!("wrote {}", path.display());
}
