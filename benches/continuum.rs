//! Bench: sharded multi-cluster scheduling vs monolithic greedy on
//! continuum-scale topologies (≥ 500 nodes), plus parity fixtures where
//! the sharded objective must stay within 5% of the monolithic one.
//!
//! Writes `BENCH_continuum.json` into the working directory so the
//! numbers can be committed as the perf-trajectory baseline.

use greengen::constraints::Constraint;
use greengen::constraints::{ConstraintGenerator, GeneratorConfig};
use greengen::continuum::{ShardedScheduler, ZonePartitioner};
use greengen::jsonio::Value;
use greengen::model::{Application, Infrastructure};
use greengen::runtime::NativeBackend;
use greengen::scheduler::{GreedyScheduler, Objective, Problem, Scheduler};
use greengen::simulate::{topology, Topology, TopologySpec};
use std::time::Instant;

fn ranked_constraints(app: &Application, infra: &Infrastructure) -> Vec<Constraint> {
    let backend = NativeBackend;
    let generated = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.8,
            use_prolog: false,
        })
        .generate(app, infra)
        .expect("constraint generation");
    greengen::ranker::Ranker::default().rank_fresh(&generated.constraints)
}

/// Best-of-N wall clock for one solve.
fn time_solver<S: Scheduler>(solver: &S, problem: &Problem, reps: usize) -> (f64, f64) {
    let mut best = f64::INFINITY;
    let mut objective = 0.0;
    for _ in 0..reps {
        let t0 = Instant::now();
        let plan = solver.schedule(problem).expect("solve");
        let dt = t0.elapsed().as_secs_f64();
        if dt < best {
            best = dt;
        }
        objective = problem.objective_value(&problem.to_assignment(&plan).unwrap());
    }
    (best, objective)
}

fn case(
    topo: Topology,
    nodes: usize,
    services: usize,
    zones: usize,
    reps: usize,
) -> Value {
    let spec = TopologySpec::new(topo, nodes, services)
        .with_zones(zones)
        .with_seed(0xBE5C);
    let (app, infra) = topology::generate(&spec);
    let constraints = ranked_constraints(&app, &infra);
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &constraints,
        objective: Objective::default(),
    };
    let (mono_s, mono_obj) = time_solver(&GreedyScheduler::default(), &problem, reps);
    let sharded = ShardedScheduler {
        partitioner: ZonePartitioner::with_zones(zones),
        ..ShardedScheduler::default()
    };
    let (shard_s, shard_obj) = time_solver(&sharded, &problem, reps);
    let sequential = ShardedScheduler {
        parallel: false,
        ..sharded
    };
    let (seq_s, _) = time_solver(&sequential, &problem, reps);
    let speedup = mono_s / shard_s.max(1e-9);
    let gap = (shard_obj - mono_obj) / mono_obj.max(1e-9);
    println!(
        "{:<22} {:>5}n x {:>5}s x {:>2}z  mono {:>8.1} ms  sharded {:>8.1} ms (seq {:>8.1} ms)  \
         speedup x{:>5.2}  objective gap {:>+6.2}%",
        topo.name(),
        nodes,
        services,
        zones,
        mono_s * 1e3,
        shard_s * 1e3,
        seq_s * 1e3,
        speedup,
        gap * 100.0
    );
    Value::object(vec![
        ("topology", Value::from(topo.name())),
        ("nodes", Value::from(nodes as f64)),
        ("services", Value::from(services as f64)),
        ("zones", Value::from(zones as f64)),
        ("monolithic_ms", Value::from(mono_s * 1e3)),
        ("sharded_ms", Value::from(shard_s * 1e3)),
        ("sharded_sequential_ms", Value::from(seq_s * 1e3)),
        ("speedup", Value::from(speedup)),
        ("monolithic_objective", Value::from(mono_obj)),
        ("sharded_objective", Value::from(shard_obj)),
        ("objective_gap", Value::from(gap)),
    ])
}

fn main() {
    println!("# continuum bench: monolithic greedy vs sharded (best of N)");
    let mut cases = Vec::new();
    // the acceptance-scale point first: 500 nodes, 1000 services
    cases.push(case(Topology::GeoRegions, 500, 1000, 8, 3));
    cases.push(case(Topology::CloudEdgeHierarchy, 600, 900, 8, 3));
    cases.push(case(Topology::IotSwarm, 500, 600, 8, 3));
    cases.push(case(Topology::HybridBurst, 500, 800, 8, 3));
    // parity fixtures: mid-size instances where the 5% objective bound
    // must hold (small ones delegate and are exactly equal by design)
    println!("# parity fixtures");
    cases.push(case(Topology::GeoRegions, 60, 120, 4, 3));
    cases.push(case(Topology::CloudEdgeHierarchy, 80, 120, 4, 3));

    let out = Value::object(vec![
        ("bench", Value::from("continuum")),
        ("status", Value::from("measured")),
        ("results", Value::array(cases)),
    ]);
    let path = std::path::Path::new("BENCH_continuum.json");
    greengen::jsonio::to_file(path, &out).expect("write BENCH_continuum.json");
    println!("wrote {}", path.display());
}
