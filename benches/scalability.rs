//! Bench E7/E8 (Fig. 2): constraint-generation latency vs application
//! size and infrastructure size (the §5.5 protocol at bench granularity;
//! the full 10-point sweep lives in `examples/scalability.rs`).

use greengen::benchkit::{Bench, BenchConfig};
use greengen::constraints::{ConstraintGenerator, GeneratorConfig};
use greengen::runtime::NativeBackend;
use greengen::simulate;
use greengen::util::Rng;
use std::time::Duration;

fn main() {
    let mut bench = Bench::new(BenchConfig {
        warmup_iters: 1,
        min_iters: 5,
        max_iters: 50,
        min_time: Duration::from_millis(500),
    });
    let backend = NativeBackend;

    // Fig 2a: growing application, fixed 50 nodes
    for services in [100, 300, 500, 1000] {
        let mut rng = Rng::new(services as u64);
        let app = simulate::random_application(&mut rng, services);
        let infra = simulate::random_infrastructure(&mut rng, 50);
        bench.bench(&format!("fig2a/components-{services}"), || {
            ConstraintGenerator::new(&backend)
                .with_config(GeneratorConfig {
                    alpha: 0.8,
                    use_prolog: false,
                })
                .generate(&app, &infra)
                .unwrap()
                .constraints
                .len()
        });
    }

    // Fig 2b: growing infrastructure, fixed 100 services
    for nodes in [20, 60, 120, 200] {
        let mut rng = Rng::new(nodes as u64 + 999);
        let app = simulate::random_application(&mut rng, 100);
        let infra = simulate::random_infrastructure(&mut rng, nodes);
        bench.bench(&format!("fig2b/nodes-{nodes}"), || {
            ConstraintGenerator::new(&backend)
                .with_config(GeneratorConfig {
                    alpha: 0.8,
                    use_prolog: false,
                })
                .generate(&app, &infra)
                .unwrap()
                .constraints
                .len()
        });
    }
    std::fs::create_dir_all("results").ok();
    bench
        .write_csv(std::path::Path::new("results/bench_scalability.csv"))
        .ok();
}
