//! Bench E7/E8 (Fig. 2): constraint-generation latency vs application
//! size and infrastructure size (the §5.5 protocol at bench granularity;
//! the full 10-point sweep lives in `examples/scalability.rs`), plus the
//! interned-ID core sweep: legacy (compile-per-score) vs compiled
//! (compile-once) scoring throughput at continuum scale, written to the
//! committed `BENCH_scalability.json` baseline. Each scoring case also
//! times one anneal pass with the observability collectors off vs on
//! (`instrumentation_overhead_pct`), pinning the cost of the `obs`
//! layer on the instrumented hot path. The `parallel_scoring` sweep
//! measures scoped-thread candidate scoring (`scheduler::parscore`) at
//! 1/2/4/8 threads up to 10k services × 2k nodes, asserting the
//! bit-identical-winner contract as it goes. `--smoke` runs a tiny
//! version of both sweeps without touching the committed baselines
//! (used by CI).

use greengen::benchkit::{Bench, BenchConfig};
use greengen::constraints::{Constraint, ConstraintGenerator, GeneratorConfig};
use greengen::jsonio::Value;
use greengen::model::{Application, Infrastructure};
use greengen::runtime::NativeBackend;
use greengen::scheduler::{CapacityState, Move, Objective, Problem, ScoreState};
use greengen::simulate;
use greengen::util::Rng;
use std::time::{Duration, Instant};

fn weighted_constraints(app: &Application, infra: &Infrastructure) -> Vec<Constraint> {
    let backend = NativeBackend;
    let mut constraints = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.8,
            use_prolog: false,
        })
        .generate(app, infra)
        .expect("constraint generation")
        .constraints;
    for (i, c) in constraints.iter_mut().enumerate() {
        c.weight = 0.1 + 0.05 * (i % 10) as f64;
    }
    constraints
}

/// Legacy vs compiled scoring throughput on one instance size.
///
/// "Legacy" is the reference `Problem::objective_value` wrapper — the
/// rebuild-per-score pattern every pre-refactor solver paid (names
/// resolved and tensors derived per call); "compiled" compiles once and
/// scores the same assignments through the dense core. The delta column
/// measures `ScoreState` per-move pricing on the compiled core.
fn scoring_case(services: usize, nodes: usize, rescored: usize, delta_moves: usize) -> Value {
    let mut rng = Rng::new((services * 31 + nodes) as u64);
    let app = simulate::random_application(&mut rng, services);
    let infra = simulate::random_infrastructure(&mut rng, nodes);
    let constraints = weighted_constraints(&app, &infra);
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &constraints,
        objective: Objective::default(),
    };
    let assignments: Vec<Vec<Option<(usize, usize)>>> = (0..rescored)
        .map(|_| {
            app.services
                .iter()
                .map(|s| {
                    if rng.chance(0.85) {
                        Some((rng.below(s.flavours.len()), rng.below(infra.nodes.len())))
                    } else {
                        None
                    }
                })
                .collect()
        })
        .collect();

    // legacy: compile-per-score (the pre-refactor cost model)
    let t0 = Instant::now();
    let mut legacy_sum = 0.0;
    for a in &assignments {
        legacy_sum += problem.objective_value(a);
    }
    let legacy_s = t0.elapsed().as_secs_f64();

    // compiled: one compilation amortised over every score
    let t0 = Instant::now();
    let compiled = problem.compile();
    let compile_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let mut compiled_sum = 0.0;
    for a in &assignments {
        compiled_sum += compiled.objective_value(a);
    }
    let compiled_s = t0.elapsed().as_secs_f64();
    assert!(
        (legacy_sum - compiled_sum).abs() < 1e-6 * (1.0 + legacy_sum.abs()),
        "legacy and compiled scoring disagree"
    );

    // per-move delta pricing on the compiled core. `ScoreState::new`
    // requires a capacity-feasible seed, so build one by random fit
    // (random slots accepted only while they fit) rather than reusing
    // the unconstrained rescore assignments — otherwise the metric
    // would mostly measure the rejection path.
    let mut cap = CapacityState::new(&infra);
    let feasible: Vec<Option<(usize, usize)>> = (0..services)
        .map(|si| {
            for _ in 0..8 {
                let fi = rng.below(app.services[si].flavours.len());
                let ni = rng.below(nodes);
                if compiled.placement_ok(si, fi, ni, &cap) {
                    let (c, r, s) = compiled.requirements(si, fi);
                    cap.take(ni, c, r, s);
                    return Some((fi, ni));
                }
            }
            None
        })
        .collect();
    let mut state = ScoreState::new(&compiled, feasible);
    let t0 = Instant::now();
    let mut priced = 0usize;
    for _ in 0..delta_moves {
        let si = rng.below(services);
        let mv = Move::Reassign {
            service: si,
            flavour: rng.below(app.services[si].flavours.len()),
            node: rng.below(nodes),
        };
        if state.delta(mv).is_some() {
            priced += 1;
        }
    }
    let delta_s = t0.elapsed().as_secs_f64();

    // observability overhead: the anneal pass is the instrumented hot
    // path (span guards + hoisted-flag counters). Same solver, same
    // problem, back to back — first with the collectors off (the
    // default: one relaxed atomic load per site), then with tracing and
    // metrics on. The collectors are global, so drain/clear and switch
    // them back off before returning.
    let solver = greengen::scheduler::solver_by_name("anneal", 7).expect("anneal solver");
    let t0 = Instant::now();
    solver.schedule(&problem).expect("anneal plain");
    let plain_s = t0.elapsed().as_secs_f64();
    greengen::obs::trace::set_enabled(true);
    greengen::obs::metrics::set_enabled(true);
    let t0 = Instant::now();
    solver.schedule(&problem).expect("anneal instrumented");
    let instrumented_s = t0.elapsed().as_secs_f64();
    greengen::obs::trace::set_enabled(false);
    greengen::obs::metrics::set_enabled(false);
    let span_count = greengen::obs::trace::drain().len();
    greengen::obs::metrics::global().clear();
    let overhead_pct = (instrumented_s - plain_s) / plain_s.max(1e-12) * 100.0;

    let legacy_per_s = rescored as f64 / legacy_s.max(1e-12);
    let compiled_per_s = rescored as f64 / compiled_s.max(1e-12);
    println!(
        "scoring {services:>5}s x {nodes:>4}n: legacy {legacy_per_s:>10.1}/s  \
         compiled {compiled_per_s:>10.1}/s  (compile {:.1} ms, {priced} deltas in {:.1} ms)",
        compile_s * 1e3,
        delta_s * 1e3
    );
    println!(
        "  anneal pass: plain {:.1} ms  instrumented {:.1} ms  \
         ({span_count} spans, overhead {overhead_pct:+.1}%)",
        plain_s * 1e3,
        instrumented_s * 1e3
    );
    Value::object(vec![
        ("services", Value::from(services as f64)),
        ("nodes", Value::from(nodes as f64)),
        ("constraints", Value::from(constraints.len() as f64)),
        ("rescored_assignments", Value::from(rescored as f64)),
        ("legacy_scores_per_s", Value::from(legacy_per_s)),
        ("compiled_scores_per_s", Value::from(compiled_per_s)),
        ("compile_ms", Value::from(compile_s * 1e3)),
        ("speedup", Value::from(compiled_per_s / legacy_per_s.max(1e-12))),
        ("delta_moves_priced", Value::from(priced as f64)),
        (
            "delta_moves_per_s",
            Value::from(priced as f64 / delta_s.max(1e-12)),
        ),
        ("anneal_plain_ms", Value::from(plain_s * 1e3)),
        ("anneal_instrumented_ms", Value::from(instrumented_s * 1e3)),
        ("anneal_spans_recorded", Value::from(span_count as f64)),
        ("instrumentation_overhead_pct", Value::from(overhead_pct)),
    ])
}

/// Parallel candidate-sweep throughput: repeated `best_reassign` sweeps
/// over a fixed service sample, once per configured thread count. Every
/// thread count must pick the identical candidate with the identical
/// delta bits (the `scheduler::parscore` determinism contract — asserted
/// here on every run, so a throughput bench doubles as an identity
/// check). Returns one row per thread count with raw candidate-scoring
/// throughput and the speedup against the 1-thread baseline.
fn parallel_case(services: usize, nodes: usize, sample: usize, threads: &[usize]) -> Vec<Value> {
    let mut rng = Rng::new((services * 17 + nodes) as u64);
    let app = simulate::random_application(&mut rng, services);
    let infra = simulate::random_infrastructure(&mut rng, nodes);
    let constraints = weighted_constraints(&app, &infra);
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &constraints,
        objective: Objective::default(),
    };
    let compiled = problem.compile();
    // capacity-feasible seed by random fit (same pattern as the delta
    // column above)
    let mut cap = CapacityState::new(&infra);
    let feasible: Vec<Option<(usize, usize)>> = (0..services)
        .map(|si| {
            for _ in 0..8 {
                let fi = rng.below(app.services[si].flavours.len());
                let ni = rng.below(nodes);
                if compiled.placement_ok(si, fi, ni, &cap) {
                    let (c, r, s) = compiled.requirements(si, fi);
                    cap.take(ni, c, r, s);
                    return Some((fi, ni));
                }
            }
            None
        })
        .collect();
    let mut state = ScoreState::new(&compiled, feasible);
    let sample_services: Vec<usize> = (0..sample).map(|_| rng.below(services)).collect();

    let mut rows = Vec::new();
    let mut baseline: Option<(f64, Vec<Option<(usize, usize, u64)>>)> = None;
    for &t in threads {
        state.set_threads(t);
        let t0 = Instant::now();
        let mut picks = Vec::with_capacity(sample_services.len());
        let mut candidates = 0usize;
        for &si in &sample_services {
            candidates += compiled.flavours(si) * nodes;
            picks.push(
                state
                    .best_reassign(si)
                    .map(|(fi, ni, d)| (fi, ni, d.total.to_bits())),
            );
        }
        let secs = t0.elapsed().as_secs_f64();
        let per_s = candidates as f64 / secs.max(1e-12);
        let speedup = match &baseline {
            None => {
                baseline = Some((secs, picks.clone()));
                1.0
            }
            Some((base_secs, base_picks)) => {
                assert_eq!(
                    *base_picks, picks,
                    "{t} threads changed a sweep winner (determinism contract broken)"
                );
                base_secs / secs.max(1e-12)
            }
        };
        println!(
            "parallel {services:>6}s x {nodes:>4}n @ {t} threads: \
             {per_s:>12.1} candidates/s  (x{speedup:.2} vs 1 thread)"
        );
        rows.push(Value::object(vec![
            ("services", Value::from(services as f64)),
            ("nodes", Value::from(nodes as f64)),
            ("threads", Value::from(t as f64)),
            ("sweeps", Value::from(sample as f64)),
            ("candidates_scored", Value::from(candidates as f64)),
            ("candidates_per_s", Value::from(per_s)),
            ("speedup_vs_1_thread", Value::from(speedup)),
        ]));
    }
    rows
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        // CI-sized determinism + throughput smoke: tiny instances so the
        // run finishes in seconds, and no baseline writes — the
        // committed BENCH_scalability.json keeps whatever it holds.
        println!("# scalability smoke (no baseline writes)");
        scoring_case(60, 20, 20, 2_000);
        parallel_case(120, 40, 8, &[1, 2]);
        return;
    }
    let mut bench = Bench::new(BenchConfig {
        warmup_iters: 1,
        min_iters: 5,
        max_iters: 50,
        min_time: Duration::from_millis(500),
    });
    let backend = NativeBackend;

    // Fig 2a: growing application, fixed 50 nodes
    for services in [100, 300, 500, 1000] {
        let mut rng = Rng::new(services as u64);
        let app = simulate::random_application(&mut rng, services);
        let infra = simulate::random_infrastructure(&mut rng, 50);
        bench.bench(&format!("fig2a/components-{services}"), || {
            ConstraintGenerator::new(&backend)
                .with_config(GeneratorConfig {
                    alpha: 0.8,
                    use_prolog: false,
                })
                .generate(&app, &infra)
                .unwrap()
                .constraints
                .len()
        });
    }

    // Fig 2b: growing infrastructure, fixed 100 services
    for nodes in [20, 60, 120, 200] {
        let mut rng = Rng::new(nodes as u64 + 999);
        let app = simulate::random_application(&mut rng, 100);
        let infra = simulate::random_infrastructure(&mut rng, nodes);
        bench.bench(&format!("fig2b/nodes-{nodes}"), || {
            ConstraintGenerator::new(&backend)
                .with_config(GeneratorConfig {
                    alpha: 0.8,
                    use_prolog: false,
                })
                .generate(&app, &infra)
                .unwrap()
                .constraints
                .len()
        });
    }
    std::fs::create_dir_all("results").ok();
    bench
        .write_csv(std::path::Path::new("results/bench_scalability.csv"))
        .ok();

    // Interned-ID core: legacy vs compiled scoring throughput, up to the
    // 1k-services × 200-nodes continuum point the sharder targets.
    println!("# scoring sweep: legacy (compile-per-score) vs compiled (compile-once)");
    let cases = vec![
        scoring_case(100, 50, 200, 20_000),
        scoring_case(300, 100, 100, 20_000),
        scoring_case(1000, 200, 40, 20_000),
    ];

    // Parallel candidate sweeps over the SoA slabs: the continuum point
    // (1k × 200) and the 10k-services × 2k-nodes target from the
    // roadmap. The 10k × 2k slabs hold ~50M (flavour, node) cells —
    // budget roughly a gigabyte of RSS for this sweep.
    println!("# parallel candidate sweeps: thread scaling on the SoA slabs");
    let mut parallel = Vec::new();
    parallel.extend(parallel_case(1000, 200, 64, &[1, 2, 4, 8]));
    parallel.extend(parallel_case(10_000, 2_000, 32, &[1, 2, 4, 8]));

    let out = Value::object(vec![
        ("bench", Value::from("scalability")),
        ("status", Value::from("measured")),
        ("results", Value::array(cases)),
        ("parallel_scoring", Value::array(parallel)),
    ]);
    let path = std::path::Path::new("BENCH_scalability.json");
    greengen::jsonio::to_file(path, &out).expect("write BENCH_scalability.json");
    println!("wrote {}", path.display());
}
