//! Bench: forecaster throughput and accuracy, plus the temporal-pass
//! cost on a continuum-scale instance.
//!
//! Writes `BENCH_forecast.json` into the working directory so the
//! numbers can be committed as the perf-trajectory baseline:
//! * per-predictor observe/predict throughput (ops/s) on a 5-region
//!   hourly stream,
//! * walk-forward MAPE at the 6 h horizon on the Scenario 3 dynamic
//!   (brown-out at hour 72),
//! * wall-clock of the temporal (node, start-slot) pass on a geo-regions
//!   fleet with one third of the services batch-deferrable.

use greengen::carbon::{CarbonIntensitySource, StaticIntensity, TraceSet};
use greengen::forecast::{
    walk_forward, AccuracyConfig, BlendedForecaster, CarbonForecaster, EwmaDrift, SeasonalNaive,
};
use greengen::jsonio::Value;
use greengen::scheduler::{
    GreedyScheduler, Objective, Problem, Scheduler, TemporalConfig, TemporalScheduler,
};
use greengen::simulate::{topology, Topology, TopologySpec};
use std::time::Instant;

const REGIONS: [&str; 5] = ["FR", "ES", "DE", "GB", "IT"];

/// observe+predict throughput of one forecaster over a synthetic stream.
fn throughput(f: &mut dyn CarbonForecaster, hours: usize) -> (f64, f64) {
    let traces = TraceSet::from_static(&StaticIntensity::europe_table2(), 0xF0CA);
    let t0 = Instant::now();
    for h in 0..hours {
        let t = h as f64 * 3600.0;
        for region in REGIONS {
            if let Some(v) = traces.intensity(region, t) {
                f.observe(region, t, v);
            }
        }
    }
    let observe_s = t0.elapsed().as_secs_f64();
    let t_last = (hours - 1) as f64 * 3600.0;
    let t0 = Instant::now();
    let mut sink = 0.0;
    for h in 1..=hours {
        for region in REGIONS {
            sink += f.predict(region, t_last, h as f64 * 3600.0).unwrap_or(0.0);
        }
    }
    let predict_s = t0.elapsed().as_secs_f64();
    assert!(sink > 0.0, "predictions must be non-trivial");
    let n = (hours * REGIONS.len()) as f64;
    (n / observe_s.max(1e-9), n / predict_s.max(1e-9))
}

/// Scenario 3 walk-forward MAPE of all three predictors (same
/// pre-/post-event trace pair the CLI and the integration tests use).
fn accuracy() -> Vec<(String, f64, f64)> {
    let (before, after) =
        greengen::config::scenarios::event_trace_sets(3).expect("scenario 3 traces");
    let event = 72.0 * 3600.0;
    let truth = |region: &str, t: f64| {
        if t < event {
            before.intensity(region, t)
        } else {
            after.intensity(region, t)
        }
    };
    let mut seasonal = SeasonalNaive::diurnal();
    let mut ewma = EwmaDrift::new();
    let mut blended = BlendedForecaster::new();
    let report = walk_forward(
        truth,
        &REGIONS,
        &AccuracyConfig {
            train_hours: 48,
            eval_hours: 48,
            horizon_hours: 6,
            step_hours: 1,
        },
        &mut [&mut seasonal, &mut ewma, &mut blended],
    );
    report
        .cases
        .iter()
        .map(|c| (c.predictor.clone(), c.mae, c.mape))
        .collect()
}

/// Temporal-pass wall clock on a fleet with deferrable services.
fn temporal_pass(nodes: usize, services: usize, slots: usize, reps: usize) -> (f64, f64, f64) {
    let spec = TopologySpec::new(Topology::GeoRegions, nodes, services)
        .with_zones(8)
        .with_seed(0xF0CA);
    let (mut app, infra) = topology::generate(&spec);
    for (i, s) in app.services.iter_mut().enumerate() {
        if i % 3 == 0 {
            s.batch = true;
        }
    }
    let mut forecaster = BlendedForecaster::new();
    for n in &infra.nodes {
        for h in 0..48 {
            let t = h as f64 * 3600.0;
            // diurnal-ish synthetic observation stream per region
            let v = n.carbon() * (1.0 - 0.3 * ((t / 86_400.0) * std::f64::consts::TAU).sin().max(0.0));
            forecaster.observe(&n.region, t, v.max(5.0));
        }
    }
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &[],
        objective: Objective::default(),
    };
    let base = GreedyScheduler::default().schedule(&problem).expect("base plan");
    let scheduler = TemporalScheduler {
        forecaster: &forecaster,
        t0: 47.0 * 3600.0,
        config: TemporalConfig {
            slot_hours: 1.0,
            horizon_slots: slots,
            max_rounds: 4,
        },
    };
    // the reactive projection is deterministic: price it once
    let mut cfg = scheduler.config;
    cfg.horizon_slots = 0;
    let reactive = TemporalScheduler {
        forecaster: scheduler.forecaster,
        t0: scheduler.t0,
        config: cfg,
    }
    .refine(&problem, &base)
    .expect("reactive")
    .projected_g;
    let mut best = f64::INFINITY;
    let mut projected = 0.0;
    for _ in 0..reps {
        let t0 = Instant::now();
        let out = scheduler.refine(&problem, &base).expect("refine");
        best = best.min(t0.elapsed().as_secs_f64());
        projected = out.projected_g;
    }
    (best, projected, reactive)
}

fn main() {
    println!("# forecast bench: predictor throughput, Scenario-3 accuracy, temporal pass");

    let mut predictors: Vec<(&str, Box<dyn CarbonForecaster>)> = vec![
        ("seasonal-naive", Box::new(SeasonalNaive::diurnal())),
        ("ewma-drift", Box::new(EwmaDrift::new())),
        ("blended", Box::new(BlendedForecaster::new())),
    ];
    let mut perf = Vec::new();
    for (name, f) in predictors.iter_mut() {
        let (obs, pred) = throughput(f.as_mut(), 96);
        println!("{name:<16} observe {obs:>12.0} ops/s   predict {pred:>12.0} ops/s");
        perf.push(Value::object(vec![
            ("predictor", Value::from(*name)),
            ("observe_ops_per_s", Value::from(obs)),
            ("predict_ops_per_s", Value::from(pred)),
        ]));
    }

    println!("# scenario-3 walk-forward, horizon 6 h");
    let mut acc = Vec::new();
    for (name, mae, mape) in accuracy() {
        println!("{name:<16} MAE {mae:>8.2} g/kWh   MAPE {mape:>7.2}%");
        acc.push(Value::object(vec![
            ("predictor", Value::from(name)),
            ("mae", Value::from(mae)),
            ("mape", Value::from(mape)),
        ]));
    }

    let (seconds, projected, reactive) = temporal_pass(200, 400, 12, 3);
    println!(
        "temporal pass    200n x 400s x 12 slots: {:.1} ms  projected {projected:.1} g \
         (reactive {reactive:.1} g)",
        seconds * 1e3
    );

    let out = Value::object(vec![
        ("bench", Value::from("forecast")),
        ("status", Value::from("measured")),
        ("throughput", Value::array(perf)),
        ("scenario3_accuracy", Value::array(acc)),
        (
            "temporal_pass",
            Value::object(vec![
                ("nodes", Value::from(200.0)),
                ("services", Value::from(400.0)),
                ("slots", Value::from(12.0)),
                ("seconds", Value::from(seconds)),
                ("projected_g", Value::from(projected)),
                ("reactive_projected_g", Value::from(reactive)),
            ]),
        ),
    ]);
    let path = std::path::Path::new("BENCH_forecast.json");
    greengen::jsonio::to_file(path, &out).expect("write BENCH_forecast.json");
    println!("wrote {}", path.display());
}
