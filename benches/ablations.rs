//! Bench E12 (ablations of DESIGN.md design choices):
//! * Prolog rule engine vs direct numeric constraint generation;
//! * first-argument fact indexing on vs off (simulated by querying a
//!   predicate whose first argument is unbound);
//! * KB memory decay on vs off (effect on constraint-set size over
//!   repeated epochs);
//! * λ attenuation on vs off in the ranker.

use greengen::benchkit::{Bench, BenchConfig};
use greengen::config::scenarios;
use greengen::constraints::{ConstraintGenerator, GeneratorConfig};
use greengen::kb::EnricherConfig;
use greengen::pipeline::{GeneratorPipeline, PipelineConfig};
use greengen::ranker::RankerConfig;
use greengen::runtime::NativeBackend;
use greengen::simulate;
use greengen::util::Rng;
use std::time::Duration;

fn main() {
    let mut bench = Bench::new(BenchConfig {
        warmup_iters: 1,
        min_iters: 5,
        max_iters: 60,
        min_time: Duration::from_millis(400),
    });
    let backend = NativeBackend;

    // --- prolog vs direct on a mid-size instance ------------------------
    let mut rng = Rng::new(0xAB1);
    let app = simulate::random_application(&mut rng, 60);
    let infra = simulate::random_infrastructure(&mut rng, 20);
    for (label, use_prolog) in [("prolog", true), ("direct", false)] {
        bench.bench(&format!("generation/{label}"), || {
            ConstraintGenerator::new(&backend)
                .with_config(GeneratorConfig {
                    alpha: 0.8,
                    use_prolog,
                })
                .generate(&app, &infra)
                .unwrap()
                .constraints
                .len()
        });
    }

    // --- ranker λ attenuation on/off -------------------------------------
    let scenario = scenarios::scenario(1).unwrap();
    for (label, attenuation) in [("lambda-0.75", 0.75), ("lambda-off", 1.0)] {
        let mut config = PipelineConfig::default();
        config.ranker = RankerConfig {
            attenuation,
            ..RankerConfig::default()
        };
        bench.bench(&format!("ranker/{label}"), || {
            let mut pipeline = GeneratorPipeline::new(config);
            pipeline.run_scenario(&scenario).unwrap().ranked.len()
        });
    }

    // --- KB decay on/off over repeated epochs -----------------------------
    for (label, decay) in [("decay-0.8", 0.8), ("decay-off", 1.0)] {
        let mut config = PipelineConfig::default();
        config.enricher = EnricherConfig {
            decay,
            ..EnricherConfig::default()
        };
        bench.bench(&format!("kb/{label}-5-epochs"), || {
            let mut pipeline = GeneratorPipeline::new(config);
            for _ in 0..5 {
                pipeline.run_scenario(&scenario).unwrap();
            }
            pipeline.kb.ck.len()
        });
    }
    std::fs::create_dir_all("results").ok();
    bench
        .write_csv(std::path::Path::new("results/bench_ablations.csv"))
        .ok();
}
