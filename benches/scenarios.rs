//! Bench E1–E5: full pipeline epoch latency per §5.3 scenario
//! (simulation + estimation + generation + KB + ranking + explanation).

use greengen::benchkit::Bench;
use greengen::config::scenarios;
use greengen::pipeline::{GeneratorPipeline, PipelineConfig};

fn main() {
    let mut bench = Bench::default();
    for n in 1..=5 {
        let scenario = scenarios::scenario(n).unwrap();
        bench.bench(&format!("pipeline/scenario{n}"), || {
            let mut pipeline = GeneratorPipeline::new(PipelineConfig::default());
            pipeline.run_scenario(&scenario).unwrap().ranked.len()
        });
    }
    // prolog vs direct generation path on scenario 1
    let scenario = scenarios::scenario(1).unwrap();
    let mut config = PipelineConfig::default();
    config.generator.use_prolog = false;
    bench.bench("pipeline/scenario1-direct", || {
        let mut pipeline = GeneratorPipeline::new(config);
        pipeline.run_scenario(&scenario).unwrap().ranked.len()
    });
    std::fs::create_dir_all("results").ok();
    bench.write_csv(std::path::Path::new("results/bench_scenarios.csv")).ok();
}
