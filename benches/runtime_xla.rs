//! Bench: analytics backends head-to-head — AOT XLA artifact execution
//! vs the native Rust mirror, across shape buckets. This is the L3↔RT
//! hot-path measurement for EXPERIMENTS.md §Perf.

use greengen::benchkit::{Bench, BenchConfig};
use greengen::runtime::{AnalyticsBackend, AnalyticsInput, NativeBackend, XlaBackend};
use greengen::util::Rng;
use std::time::Duration;

fn input(rng: &mut Rng, rows: usize, nodes: usize) -> AnalyticsInput {
    AnalyticsInput {
        e: (0..rows).map(|_| rng.range(0.0, 5.0) as f32).collect(),
        c: (0..nodes).map(|_| rng.range(10.0, 600.0) as f32).collect(),
        mask: (0..rows * nodes)
            .map(|_| if rng.chance(0.8) { 1.0 } else { 0.0 })
            .collect(),
        pool: (0..rows / 4).map(|_| rng.range(0.0, 100.0) as f32).collect(),
        alpha: 0.8,
    }
}

fn main() {
    let mut bench = Bench::new(BenchConfig {
        warmup_iters: 2,
        min_iters: 10,
        max_iters: 200,
        min_time: Duration::from_millis(400),
    });
    let mut rng = Rng::new(0xBE);
    let native = NativeBackend;
    let xla = XlaBackend::from_default_artifacts().ok();
    if xla.is_none() {
        eprintln!("artifacts missing: run `make artifacts` for the XLA side");
    }

    for (rows, nodes) in [(15usize, 5usize), (64, 8), (100, 30), (512, 128), (1000, 100)] {
        let inp = input(&mut rng, rows, nodes);
        bench.bench(&format!("native/{rows}x{nodes}"), || {
            native.run(&inp).unwrap().tau
        });
        if let Some(xla) = &xla {
            // warm the executable cache once so compile time is excluded
            let _ = xla.run(&inp).unwrap();
            bench.bench(&format!("xla/{rows}x{nodes}"), || xla.run(&inp).unwrap().tau);
        }
    }
    std::fs::create_dir_all("results").ok();
    bench
        .write_csv(std::path::Path::new("results/bench_runtime.csv"))
        .ok();
}
