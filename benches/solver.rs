//! Bench: the solver ladder — greedy vs simulated annealing vs
//! large-neighbourhood search vs the portfolio — on topology-fleet
//! instances, measuring wall clock and achieved objective.
//!
//! Writes `BENCH_solver.json` into the working directory so the numbers
//! can be committed as the perf-trajectory baseline (same convention as
//! `BENCH_continuum.json`).

use greengen::constraints::Constraint;
use greengen::constraints::{ConstraintGenerator, GeneratorConfig};
use greengen::jsonio::Value;
use greengen::model::{Application, Infrastructure};
use greengen::runtime::NativeBackend;
use greengen::scheduler::{solver_by_name, Objective, Problem};
use greengen::simulate::{topology, Topology, TopologySpec};
use std::time::Instant;

const SOLVERS: [&str; 4] = ["greedy", "anneal", "lns", "portfolio"];

fn ranked_constraints(app: &Application, infra: &Infrastructure) -> Vec<Constraint> {
    let backend = NativeBackend;
    let generated = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.8,
            use_prolog: false,
        })
        .generate(app, infra)
        .expect("constraint generation");
    greengen::ranker::Ranker::default().rank_fresh(&generated.constraints)
}

fn case(topo: Topology, nodes: usize, services: usize, reps: usize) -> Value {
    let spec = TopologySpec::new(topo, nodes, services)
        .with_zones(8)
        .with_seed(0x50_1BE2);
    let (app, infra) = topology::generate(&spec);
    let constraints = ranked_constraints(&app, &infra);
    let problem = Problem {
        app: &app,
        infra: &infra,
        constraints: &constraints,
        objective: Objective::default(),
    };
    let mut fields: Vec<(String, Value)> = vec![
        ("topology".to_string(), Value::from(topo.name())),
        ("nodes".to_string(), Value::from(nodes as f64)),
        ("services".to_string(), Value::from(services as f64)),
    ];
    let mut greedy_obj = f64::NAN;
    print!(
        "{:<22} {:>5}n x {:>5}s ",
        topo.name(),
        nodes,
        services
    );
    for name in SOLVERS {
        let solver = solver_by_name(name, 0xBE2C).expect("registry solver");
        let mut best = f64::INFINITY;
        let mut objective = 0.0;
        for _ in 0..reps {
            let t0 = Instant::now();
            let plan = solver.schedule(&problem).expect("solve");
            let dt = t0.elapsed().as_secs_f64();
            if dt < best {
                best = dt;
            }
            objective = problem.objective_value(&problem.to_assignment(&plan).unwrap());
        }
        if name == "greedy" {
            greedy_obj = objective;
        }
        let gain = (greedy_obj - objective) / greedy_obj.abs().max(1e-9);
        print!(
            " | {name} {:>8.1} ms obj {:>10.2} ({:+.2}%)",
            best * 1e3,
            objective,
            -gain * 100.0
        );
        fields.push((format!("{name}_ms"), Value::from(best * 1e3)));
        fields.push((format!("{name}_objective"), Value::from(objective)));
    }
    println!();
    Value::object(fields)
}

fn main() {
    println!("# solver bench: the ladder on topology fleets (best of N)");
    let mut cases = Vec::new();
    // the acceptance criterion band: 50+ services on every preset
    cases.push(case(Topology::GeoRegions, 60, 120, 3));
    cases.push(case(Topology::CloudEdgeHierarchy, 80, 120, 3));
    cases.push(case(Topology::IotSwarm, 60, 80, 3));
    cases.push(case(Topology::HybridBurst, 60, 100, 3));
    // one continuum-scale point
    cases.push(case(Topology::GeoRegions, 300, 600, 1));

    let out = Value::object(vec![
        ("bench", Value::from("solver")),
        ("status", Value::from("measured")),
        ("results", Value::array(cases)),
    ]);
    let path = std::path::Path::new("BENCH_solver.json");
    greengen::jsonio::to_file(path, &out).expect("write BENCH_solver.json");
    println!("wrote {}", path.display());
}
