//! Scalability study (§5.5, Fig. 2): energy and execution time of the
//! Green-aware Constraint Generator as the application (Fig. 2a) and the
//! infrastructure (Fig. 2b) grow.
//!
//! Application-level: components 100 → 1000 in steps of 100, fixed nodes.
//! Infrastructure-level: nodes 20 → 200, fixed application. Each point
//! averages `--reps` runs (paper: 10; default here 5 to keep the example
//! snappy — pass `--reps 10` for the paper's protocol).
//!
//! Writes `results/fig2a.csv` and `results/fig2b.csv`.
//!
//! ```sh
//! cargo run --release --example scalability -- [--reps 10] [--xla]
//! ```

use greengen::cliargs::Args;
use greengen::constraints::{ConstraintGenerator, ConstraintLibrary, GeneratorConfig};
use greengen::explain::ExplainabilityGenerator;
use greengen::kb::ConstraintEntry;
use greengen::ranker::Ranker;
use greengen::runtime::{AnalyticsBackend, NativeBackend, XlaBackend};
use greengen::simulate;
use greengen::telemetry::EnergyMeter;
use greengen::util::Rng;

fn sweep(
    label: &str,
    points: &[(usize, usize)],
    reps: usize,
    backend: &dyn AnalyticsBackend,
) -> greengen::Result<String> {
    println!("--- {label} (backend {}, {reps} reps/point) ---", backend.name());
    println!(
        "{:>10} {:>8} {:>12} {:>14} {:>12}",
        "components", "nodes", "time (s)", "energy (kWh)", "constraints"
    );
    let mut csv = String::from("components,nodes,mean_seconds,sd_seconds,mean_kwh,constraints\n");
    for &(services, nodes) in points {
        let mut times = Vec::new();
        let mut kwhs = Vec::new();
        let mut n_constraints = 0usize;
        for rep in 0..reps {
            let mut rng = Rng::new((services * 13 + nodes * 7 + rep) as u64);
            let app = simulate::random_application(&mut rng, services);
            let infra = simulate::random_infrastructure(&mut rng, nodes);
            // full §5.5 protocol: generation AND the explainability report
            let mut meter = EnergyMeter::default();
            let generator = ConstraintGenerator::new(backend).with_config(GeneratorConfig {
                alpha: 0.8,
                use_prolog: false,
            });
            let result = meter.measure("generate", || generator.generate(&app, &infra))?;
            let entries: Vec<ConstraintEntry> = result
                .constraints
                .iter()
                .map(|c| ConstraintEntry {
                    constraint: c.clone(),
                    mu: 1.0,
                    generated_at: 0.0,
                })
                .collect();
            let ranked = meter.measure("rank", || Ranker::default().rank(&entries));
            let report = meter.measure("explain", || {
                ExplainabilityGenerator::report(&ConstraintLibrary::default(), &ranked)
                    .render_text()
                    .len()
            });
            let _ = report;
            let (t, e) = meter.totals();
            times.push(t);
            kwhs.push(e);
            n_constraints = ranked.len();
        }
        let mean_t = times.iter().sum::<f64>() / reps as f64;
        let sd_t = (times.iter().map(|t| (t - mean_t).powi(2)).sum::<f64>() / reps as f64).sqrt();
        let mean_e = kwhs.iter().sum::<f64>() / reps as f64;
        println!(
            "{services:>10} {nodes:>8} {mean_t:>12.4} {mean_e:>14.3e} {n_constraints:>12}"
        );
        csv.push_str(&format!(
            "{services},{nodes},{mean_t:.6},{sd_t:.6},{mean_e:.6e},{n_constraints}\n"
        ));
    }
    Ok(csv)
}

fn main() -> greengen::Result<()> {
    let args = Args::from_env()?;
    let reps = args.usize_or("reps", 5)?;
    std::fs::create_dir_all("results")?;

    let xla = if args.flag("xla") {
        Some(XlaBackend::from_default_artifacts()?)
    } else {
        None
    };
    let native = NativeBackend;
    let backend: &dyn AnalyticsBackend = match &xla {
        Some(b) => b,
        None => &native,
    };

    // Fig. 2a: application-level scalability (components 100..1000).
    let points_a: Vec<(usize, usize)> = (1..=10).map(|i| (i * 100, 50)).collect();
    let csv = sweep("Fig 2a: application-level", &points_a, reps, backend)?;
    std::fs::write("results/fig2a.csv", csv)?;

    // Fig. 2b: infrastructure-level scalability (nodes 20..200).
    let points_b: Vec<(usize, usize)> = (1..=10).map(|i| (100, i * 20)).collect();
    let csv = sweep("Fig 2b: infrastructure-level", &points_b, reps, backend)?;
    std::fs::write("results/fig2b.csv", csv)?;

    println!("\nwrote results/fig2a.csv, results/fig2b.csv");
    Ok(())
}
