//! Write the Online Boutique application + EU infrastructure fixtures as
//! JSON in the `greengen generate --app/--infra` input format.
//!
//! Usage: `cargo run --release --example dump_fixtures -- [DIR]`
//! (defaults to the current directory; writes `app.json` + `infra.json`).
//!
//! The CI "Generation parallel smoke" step uses this to feed the CLI a
//! deterministic instance and byte-compare `--threads N` output against
//! the sequential run.

use greengen::model::EnergyProfile;

fn main() {
    let dir = std::path::PathBuf::from(std::env::args().nth(1).unwrap_or_else(|| ".".into()));
    let mut app = greengen::config::boutique::application();
    // Pre-enrich energy profiles from the paper's Table 1: the CLI's
    // generate path reads profiles from the file instead of a monitoring
    // store.
    for (service, flavour, wh, _, _) in greengen::config::boutique::TABLE1 {
        app.service_mut(service)
            .expect("Table 1 service exists")
            .flavour_mut(flavour)
            .expect("Table 1 flavour exists")
            .energy = Some(EnergyProfile {
            kwh: wh / 1000.0,
            samples: 1,
        });
    }
    let infra = greengen::config::boutique::eu_infrastructure();
    let app_path = dir.join("app.json");
    let infra_path = dir.join("infra.json");
    greengen::jsonio::to_file(&app_path, &app.to_json()).expect("write app.json");
    greengen::jsonio::to_file(&infra_path, &infra.to_json()).expect("write infra.json");
    println!("wrote {} and {}", app_path.display(), infra_path.display());
}
