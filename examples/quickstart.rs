//! Quickstart: run the Green-aware Constraint Generator on the paper's
//! baseline scenario (Online Boutique × the European infrastructure) and
//! print the ranked constraints, the §5.4 explainability report, and the
//! three scheduler-adapter dialects.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use greengen::adapter::{JsonAdapter, MiniZincAdapter, PrologAdapter, SchedulerAdapter};
use greengen::config::scenarios;
use greengen::pipeline::{GeneratorPipeline, PipelineConfig};

fn main() -> greengen::Result<()> {
    // 1. Pick the paper's Scenario 1 and build the pipeline. Use the XLA
    //    (AOT HLO artifact) backend when artifacts are built, else native.
    let scenario = scenarios::scenario(1)?;
    let mut pipeline = match GeneratorPipeline::with_xla(PipelineConfig::default(), "artifacts")
    {
        Ok(p) => p,
        Err(_) => GeneratorPipeline::new(PipelineConfig::default()),
    };
    println!("backend: {}\n", pipeline.backend_name());

    // 2. One generation epoch: simulate monitoring, learn profiles,
    //    generate + rank constraints.
    let outcome = pipeline.run_scenario(&scenario)?;
    println!(
        "tau = {:.2} gCO2eq, {} constraints survive the ranker\n",
        outcome.raw.tau,
        outcome.ranked.len()
    );

    // 3. The paper's presentation syntax.
    println!("--- constraints (prolog dialect) ---");
    print!("{}", PrologAdapter.format(&outcome.ranked));

    // 4. Explainability report (§5.4).
    println!("\n--- explainability report (top 3) ---");
    for entry in outcome.report.entries.iter().take(3) {
        println!("{}\n", entry.rationale);
    }

    // 5. Other scheduler dialects.
    println!("--- json dialect (first 400 chars) ---");
    let json = JsonAdapter.format(&outcome.ranked);
    println!("{}...", &json[..json.len().min(400)]);
    println!("\n--- minizinc dialect (first 400 chars) ---");
    let mzn = MiniZincAdapter.format(&outcome.ranked);
    println!("{}...", &mzn[..mzn.len().min(400)]);
    Ok(())
}
