//! Scenario sweep (§5.3): run all five validation scenarios and verify
//! the paper's qualitative expectations hold, writing a Markdown results
//! file to `results/scenarios.md`.
//!
//! Expectations checked:
//! * S1: frontend/large on Italy is the top constraint (w = 1.0), the GB
//!   variant weighs ≈ 0.636, and no Affinity constraint survives.
//! * S2: the top constraints move to Florida (CI 570) and weights for
//!   Washington/California/NewYork ≈ 0.428/0.412/0.414.
//! * S3: France (16 → 376) becomes an avoided node.
//! * S4: with the optimised frontend, productcatalog/large on Italy takes
//!   weight 1.0 and currency/tiny ≈ 0.89.
//! * S5: with ×15000 traffic, Affinity constraints survive the ranker.
//!
//! ```sh
//! cargo run --release --example scenario_sweep
//! ```

use greengen::config::scenarios;
use greengen::constraints::ConstraintKind;
use greengen::pipeline::{GeneratorPipeline, PipelineConfig};

fn main() -> greengen::Result<()> {
    std::fs::create_dir_all("results")?;
    let mut md = String::from("# Scenario sweep (§5.3)\n");
    let mut failures = Vec::new();

    for n in 1..=5 {
        let scenario = scenarios::scenario(n)?;
        let mut pipeline = GeneratorPipeline::new(PipelineConfig::default());
        let outcome = pipeline.run_scenario(&scenario)?;
        println!("=== Scenario {n}: {} ===", scenario.name);
        md.push_str(&format!(
            "\n## Scenario {n}: {} — {}\n\ntau = {:.2} gCO2eq, {} constraints\n\n```prolog\n",
            scenario.name,
            scenario.description,
            outcome.raw.tau,
            outcome.ranked.len()
        ));
        for c in &outcome.ranked {
            println!("{}", c.render_prolog());
            md.push_str(&c.render_prolog());
            md.push('\n');
        }
        md.push_str("```\n");

        let weight_of = |svc: &str, fl: &str, node: &str| -> Option<f64> {
            outcome.ranked.iter().find_map(|c| match &c.kind {
                ConstraintKind::AvoidNode {
                    service,
                    flavour,
                    node: nd,
                } if service == svc && flavour == fl && nd == node => Some(c.weight),
                _ => None,
            })
        };
        let mut expect = |label: &str, ok: bool| {
            println!("  [{}] {label}", if ok { "ok" } else { "FAIL" });
            if !ok {
                failures.push(format!("scenario {n}: {label}"));
            }
        };

        match n {
            1 => {
                expect(
                    "frontend/large avoided on italy with w=1.0",
                    weight_of("frontend", "large", "italy")
                        .map(|w| (w - 1.0).abs() < 1e-9)
                        .unwrap_or(false),
                );
                expect(
                    "frontend/large on greatbritain w≈0.636",
                    weight_of("frontend", "large", "greatbritain")
                        .map(|w| (w - 0.636).abs() < 0.02)
                        .unwrap_or(false),
                );
                expect(
                    "no affinity constraints survive",
                    outcome
                        .ranked
                        .iter()
                        .all(|c| !matches!(c.kind, ConstraintKind::Affinity { .. })),
                );
            }
            2 => {
                expect(
                    "frontend/large avoided on florida with w=1.0",
                    weight_of("frontend", "large", "florida")
                        .map(|w| (w - 1.0).abs() < 1e-9)
                        .unwrap_or(false),
                );
                for (node, w_paper) in
                    [("washington", 0.428), ("california", 0.412), ("newyork", 0.414)]
                {
                    expect(
                        &format!("frontend/large on {node} w≈{w_paper}"),
                        weight_of("frontend", "large", node)
                            .map(|w| (w - w_paper).abs() < 0.02)
                            .unwrap_or(false),
                    );
                }
            }
            3 => {
                expect(
                    "france becomes an avoided node after brown-out",
                    outcome.ranked.iter().any(|c| matches!(&c.kind,
                        ConstraintKind::AvoidNode { node, .. } if node == "france")),
                );
                expect(
                    "frontend/large on france outweighs greatbritain (376 > 213)",
                    match (
                        weight_of("frontend", "large", "france"),
                        weight_of("frontend", "large", "greatbritain"),
                    ) {
                        (Some(fr), Some(gb)) => fr > gb,
                        _ => false,
                    },
                );
            }
            4 => {
                expect(
                    "productcatalog/large on italy takes w=1.0",
                    weight_of("productcatalog", "large", "italy")
                        .map(|w| (w - 1.0).abs() < 1e-9)
                        .unwrap_or(false),
                );
                expect(
                    "currency/tiny on italy w≈0.89",
                    weight_of("currency", "tiny", "italy")
                        .map(|w| (w - 0.89).abs() < 0.02)
                        .unwrap_or(false),
                );
                expect(
                    "frontend no longer the top constraint",
                    weight_of("frontend", "large", "italy")
                        .map(|w| w < 0.6)
                        .unwrap_or(true),
                );
            }
            5 => {
                let affinities = outcome
                    .ranked
                    .iter()
                    .filter(|c| matches!(c.kind, ConstraintKind::Affinity { .. }))
                    .count();
                expect(
                    "affinity constraints survive under x15000 traffic",
                    affinities > 0,
                );
                md.push_str(&format!("\n{affinities} affinity constraints survived.\n"));
            }
            _ => unreachable!(),
        }
    }

    std::fs::write("results/scenarios.md", &md)?;
    println!("\nwrote results/scenarios.md");
    if failures.is_empty() {
        println!("all paper expectations reproduced ✓");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("FAILED: {f}");
        }
        Err(greengen::Error::other(format!(
            "{} expectation(s) failed",
            failures.len()
        )))
    }
}
