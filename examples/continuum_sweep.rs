//! Continuum sweep: run the sharded multi-cluster scheduler across every
//! named topology shape and compare it against the monolithic greedy
//! solver, then demonstrate incremental re-planning under per-zone carbon
//! drift.
//!
//! ```sh
//! cargo run --release --example continuum_sweep
//! ```

use greengen::constraints::{Constraint, ConstraintGenerator, GeneratorConfig};
use greengen::continuum::{IncrementalReplanner, ShardedScheduler, ZonePartitioner};
use greengen::model::{Application, Infrastructure};
use greengen::runtime::NativeBackend;
use greengen::scheduler::{evaluate, GreedyScheduler, Objective, Problem, Scheduler};
use greengen::simulate::{topology, Topology, TopologySpec};
use std::time::Instant;

fn learn_constraints(app: &Application, infra: &Infrastructure) -> Vec<Constraint> {
    let backend = NativeBackend;
    let generated = ConstraintGenerator::new(&backend)
        .with_config(GeneratorConfig {
            alpha: 0.8,
            use_prolog: false,
        })
        .generate(app, infra)
        .expect("generation");
    greengen::ranker::Ranker::default().rank_fresh(&generated.constraints)
}

fn main() -> greengen::Result<()> {
    const NODES: usize = 200;
    const SERVICES: usize = 400;
    const ZONES: usize = 6;

    println!("=== sharded vs monolithic across the topology fleet ===");
    println!("{NODES} nodes x {SERVICES} services x {ZONES} zones\n");
    for topo in Topology::ALL {
        let spec = TopologySpec::new(topo, NODES, SERVICES)
            .with_zones(ZONES)
            .with_seed(0x5EED);
        let (app, infra) = topology::generate(&spec);
        let constraints = learn_constraints(&app, &infra);
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };

        let t0 = Instant::now();
        let mono = GreedyScheduler::default().schedule(&problem)?;
        let mono_s = t0.elapsed().as_secs_f64();
        let m_mono = evaluate(&problem, &mono)?;

        let sharded = ShardedScheduler {
            partitioner: ZonePartitioner::with_zones(ZONES),
            ..ShardedScheduler::default()
        };
        let t0 = Instant::now();
        let (plan, stats) = sharded.schedule_with_stats(&problem)?;
        let shard_s = t0.elapsed().as_secs_f64();
        let m_shard = evaluate(&problem, &plan)?;

        println!(
            "{:<22} mono {:>7.1} ms / {:>9.1} g   sharded {:>7.1} ms / {:>9.1} g   \
             x{:.2} ({} zones, {} repaired)",
            topo.name(),
            mono_s * 1e3,
            m_mono.emissions_g,
            shard_s * 1e3,
            m_shard.emissions_g,
            mono_s / shard_s.max(1e-9),
            stats.zones,
            stats.repair_placed,
        );
    }

    println!("\n=== incremental re-planning under per-zone carbon drift ===");
    let spec = TopologySpec::new(Topology::GeoRegions, NODES, SERVICES)
        .with_zones(ZONES)
        .with_seed(0x5EED);
    let (app, mut infra) = topology::generate(&spec);
    let constraints = learn_constraints(&app, &infra);
    let mut rp = IncrementalReplanner::new(ShardedScheduler {
        partitioner: ZonePartitioner::with_zones(ZONES),
        ..ShardedScheduler::default()
    });
    for epoch in 0..6 {
        if epoch > 0 {
            // one zone's grid browns out / recovers; the rest is stable
            let zone = format!("z{:02}", epoch % ZONES);
            for n in &mut infra.nodes {
                if n.zone.as_deref() == Some(zone.as_str()) {
                    let factor = if epoch % 2 == 0 { 0.5 } else { 2.0 };
                    n.profile.carbon = Some((n.carbon() * factor).clamp(10.0, 650.0));
                }
            }
        }
        let problem = Problem {
            app: &app,
            infra: &infra,
            constraints: &constraints,
            objective: Objective::default(),
        };
        let t0 = Instant::now();
        let outcome = rp.replan(&problem)?;
        println!(
            "epoch {epoch}: re-solved {}/{} zones, reused {} placements, {:.1} ms",
            outcome.dirty_zones.len(),
            outcome.total_zones,
            outcome.reused_placements,
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
    Ok(())
}
