//! Threshold analysis (§5.6): regenerate Table 4 (constraint count vs
//! quantile level) and the Fig. 3 savings distribution, on the paper's
//! setup — 100 services × 100 nodes with randomised realistic profiles.
//!
//! Writes `results/table4.csv` and `results/fig3.csv`, prints an ASCII
//! rendition of Fig. 3.
//!
//! ```sh
//! cargo run --release --example threshold_analysis
//! ```

use greengen::constraints::{ConstraintGenerator, GeneratorConfig};
use greengen::runtime::NativeBackend;
use greengen::simulate;
use greengen::util::Rng;

const LEVELS: &[f64] = &[0.90, 0.85, 0.80, 0.75, 0.70, 0.65, 0.60, 0.55, 0.50];

fn main() -> greengen::Result<()> {
    std::fs::create_dir_all("results")?;
    let mut rng = Rng::new(0x7A81e4);
    let app = simulate::random_application(&mut rng, 100);
    let infra = simulate::random_infrastructure(&mut rng, 100);
    let backend = NativeBackend;

    // --- Table 4 ---------------------------------------------------------
    // `generated` = raw Eq. 3/4 candidates above tau; `ranked` = what
    // survives the Constraints Ranker (w >= 0.1 after attenuation) — the
    // set the scheduler actually receives. The paper's exact counting
    // protocol is under-specified; we report both (see EXPERIMENTS.md E9).
    println!("Table 4 — generated constraints per quantile threshold");
    println!(
        "{:<10} {:>12} {:>12} {:>10}",
        "quantile", "tau(gCO2eq)", "generated", "ranked"
    );
    let mut table4 = String::from("quantile,tau,generated,ranked\n");
    let mut per_level: Vec<(f64, Vec<f64>)> = Vec::new();
    for &level in LEVELS {
        let generator = ConstraintGenerator::new(&backend).with_config(GeneratorConfig {
            alpha: level,
            use_prolog: false,
        });
        let result = generator.generate(&app, &infra)?;
        let entries: Vec<greengen::kb::ConstraintEntry> = result
            .constraints
            .iter()
            .map(|c| greengen::kb::ConstraintEntry {
                constraint: c.clone(),
                mu: 1.0,
                generated_at: 0.0,
            })
            .collect();
        let ranked = greengen::ranker::Ranker::default().rank(&entries);
        println!(
            "{:<10} {:>12.2} {:>12} {:>10}",
            level,
            result.tau,
            result.constraints.len(),
            ranked.len()
        );
        table4.push_str(&format!(
            "{level},{:.4},{},{}\n",
            result.tau,
            result.constraints.len(),
            ranked.len()
        ));
        let mut ems: Vec<f64> = result.constraints.iter().map(|c| c.em).collect();
        ems.sort_by(|a, b| b.partial_cmp(a).unwrap());
        per_level.push((level, ems));
    }
    std::fs::write("results/table4.csv", &table4)?;

    // Paper shape check: count grows super-linearly as the level drops.
    let counts: Vec<usize> = per_level.iter().map(|(_, e)| e.len()).collect();
    for w in counts.windows(2) {
        assert!(w[1] >= w[0], "count must grow as the quantile drops: {counts:?}");
    }
    let early_growth = counts[2] as f64 - counts[0] as f64; // 0.90 -> 0.80
    let late_growth = counts[8] as f64 - counts[6] as f64; // 0.60 -> 0.50
    println!(
        "\ngrowth 0.90→0.80: +{early_growth}, growth 0.60→0.50: +{late_growth} \
         (accelerating: {})",
        late_growth > early_growth
    );

    // --- Fig. 3 ------------------------------------------------------------
    // Every constraint of the loosest level, ordered by impact; colour =
    // the strictest level that would still generate it.
    let loosest = &per_level.last().unwrap().1;
    let mut fig3 = String::from("rank,em_gCO2eq,strictest_level\n");
    for (i, em) in loosest.iter().enumerate() {
        let strictest = per_level
            .iter()
            .find(|(_, ems)| ems.contains(em))
            .map(|(l, _)| *l)
            .unwrap_or(0.5);
        fig3.push_str(&format!("{},{:.4},{}\n", i + 1, em, strictest));
    }
    std::fs::write("results/fig3.csv", &fig3)?;

    println!("\nFig. 3 — potential emission savings per constraint (top 60, ASCII)");
    let max = loosest.first().copied().unwrap_or(1.0);
    for (i, em) in loosest.iter().take(60).enumerate() {
        let bar = "#".repeat(((em / max) * 60.0).ceil() as usize);
        println!("{:>4} {:>10.1} {bar}", i + 1, em);
    }
    println!(
        "\n({} constraints at q0.50; wrote results/table4.csv, results/fig3.csv)",
        loosest.len()
    );
    Ok(())
}
