//! End-to-end driver (DESIGN.md experiment E11): the full three-layer
//! system on a realistic workload.
//!
//! Simulates 7 days of Online Boutique on the European infrastructure
//! with diurnal carbon-intensity and load dynamics. Every 6 hours the
//! Rust coordinator re-runs the Green-aware Constraint Generator (L2/L1
//! analytics through the AOT-compiled XLA artifact when available),
//! feeds the ranked constraints to the constraint-aware scheduler, and
//! measures ground-truth emissions against three baselines. A second
//! pass injects node failures (FREEDA's failure-resilience setting).
//!
//! Outputs `results/adaptive.csv` and a summary; EXPERIMENTS.md records
//! the headline numbers.
//!
//! ```sh
//! cargo run --release --example adaptive_loop
//! ```

use greengen::config::scenarios;
use greengen::pipeline::{AdaptiveConfig, AdaptiveLoop, GeneratorPipeline, PipelineConfig};
use greengen::scheduler::Objective;

fn run_pass(label: &str, failure_rate: f64, csv: &mut String) -> greengen::Result<()> {
    let scenario = scenarios::scenario(1)?;
    let pipeline = match GeneratorPipeline::with_xla(PipelineConfig::default(), "artifacts") {
        Ok(p) => p,
        Err(_) => GeneratorPipeline::new(PipelineConfig::default()),
    };
    println!("=== {label} (backend: {}) ===", pipeline.backend_name());
    let mut looper = AdaptiveLoop::with_pipeline(
        pipeline,
        AdaptiveConfig {
            hours: 168, // 7 days
            regen_every: 6,
            failure_rate,
            objective: Objective::default(),
            seed: 0xE2E,
            incremental: false,
            zones: 0,
            horizon: 0,
        },
    );
    let summary = looper.run(&scenario)?;

    println!("hour  #constraints  constrained_g  cost_only_g  random_g  oracle_g  failed");
    for e in &summary.epochs {
        println!(
            "{:>4}  {:>12}  {:>13.1}  {:>11.1}  {:>8.1}  {:>8.1}  {}",
            e.hour,
            e.constraints,
            e.constrained_g,
            e.cost_only_g,
            e.random_g,
            e.oracle_g,
            e.failed_node.as_deref().unwrap_or("-")
        );
        csv.push_str(&format!(
            "{label},{},{},{:.3},{:.3},{:.3},{:.3},{}\n",
            e.hour,
            e.constraints,
            e.constrained_g,
            e.cost_only_g,
            e.random_g,
            e.oracle_g,
            e.failed_node.as_deref().unwrap_or("")
        ));
    }
    println!(
        "\n{label} totals (gCO2eq/7d): constrained={:.0} cost-only={:.0} random={:.0} oracle={:.0}",
        summary.total_constrained_g,
        summary.total_cost_only_g,
        summary.total_random_g,
        summary.total_oracle_g
    );
    println!(
        "{label}: emission reduction vs cost-only = {:.1}%, oracle recovery = {:.1}%\n",
        summary.reduction_vs_cost_only() * 100.0,
        summary.oracle_recovery() * 100.0
    );

    // sanity: the whole point of the paper
    assert!(
        summary.total_constrained_g < summary.total_cost_only_g,
        "constraints failed to reduce emissions"
    );
    Ok(())
}

fn main() -> greengen::Result<()> {
    std::fs::create_dir_all("results")?;
    let mut csv = String::from(
        "pass,hour,constraints,constrained_g,cost_only_g,random_g,oracle_g,failed_node\n",
    );
    run_pass("steady", 0.0, &mut csv)?;
    run_pass("failures", 0.25, &mut csv)?;
    std::fs::write("results/adaptive.csv", csv)?;
    println!("wrote results/adaptive.csv");
    Ok(())
}
